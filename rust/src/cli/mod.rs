//! Minimal CLI argument parser (clap is unavailable offline): a
//! subcommand plus `--key value` / `--flag` pairs with typed accessors and
//! generated usage text.

use std::collections::BTreeMap;

use crate::core::{Error, Result};
use crate::coordinator::config::parse_bytes;

/// Parsed command line: subcommand + options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first token is the subcommand; `--key value`
    /// pairs and bare `--flag`s follow.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --option, got {tok:?}")))?
                .to_string();
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    opts.insert(key, it.next().unwrap());
                }
                _ => flags.push(key),
            }
        }
        Ok(Args { command, opts, flags })
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.opts.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, name: &str) -> Option<String> {
        self.opts.get(name).cloned()
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: bad integer {v:?}"))),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: bad float {v:?}"))),
        }
    }

    /// Parse a byte size (`--size 1MiB`).
    pub fn bytes(&self, name: &str, default: usize) -> Result<usize> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => parse_bytes(v),
        }
    }

    /// Comma-separated list of usizes (`--ranks 8,16,32`).
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.opts.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| Error::Config(format!("--{name}: bad integer {t:?}")))
                })
                .collect(),
        }
    }

    /// Comma-separated byte sizes (`--sizes 1KiB,64KiB,4MiB`).
    pub fn bytes_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.opts.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v.split(',').map(|t| parse_bytes(t.trim())).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = args("run --ranks 16 --alg pat:2 --verbose --size 4KiB");
        assert_eq!(a.command, "run");
        assert_eq!(a.usize("ranks", 0).unwrap(), 16);
        assert_eq!(a.str("alg", ""), "pat:2");
        assert!(a.flag("verbose"));
        assert_eq!(a.bytes("size", 0).unwrap(), 4096);
    }

    #[test]
    fn lists() {
        let a = args("sweep --ranks 8,16,32 --sizes 1KiB,1MiB");
        assert_eq!(a.usize_list("ranks", &[]).unwrap(), vec![8, 16, 32]);
        assert_eq!(a.bytes_list("sizes", &[]).unwrap(), vec![1024, 1 << 20]);
    }

    #[test]
    fn defaults() {
        let a = args("run");
        assert_eq!(a.usize("ranks", 8).unwrap(), 8);
        assert_eq!(a.str("alg", "pat_auto"), "pat_auto");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn rejects_positional_garbage() {
        assert!(Args::parse(vec!["run".into(), "oops".into()]).is_err());
    }
}
