//! P6 / P7 — ablations on the two design choices the paper discusses:
//!
//! 1. **Linear-phase ordering** (paper: "Another possible schedule is to
//!    send close first, then far"): depth-first (the shipped schedule)
//!    versus dimension-major. Same step count and wire traffic, but the
//!    mirrored reduce-scatter's accumulator footprint differs
//!    asymptotically — a·log2(n/a) versus Θ(n/2).
//!
//! 2. **The local linear-part cost γ** (paper §Performance: "depending on
//!    the amount of optimization we can achieve on those linear parts …
//!    the algorithm may look linear or logarithmic"): sweep the per-chunk
//!    handling cost and watch PAT's advantage over Ring erode.

use patcol::core::{Algorithm, Collective};
use patcol::report::Report;
use patcol::sched::pat::{self, LinearOrder};
use patcol::sched::verify::verify_program;
use patcol::sched::{self};
use patcol::sim::{simulate, CostModel, Topology};
use patcol::util::json::Json;
use patcol::util::table::{fmt_time_s, Table};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = Report::new("ablation_ordering");

    // --- ablation 1: DFS vs dim-major ordering ----------------------------
    println!("\nordering ablation — reduce-scatter accumulator slots:");
    let mut t = Table::new(["ranks", "depth-first", "dim-major", "ratio"]);
    let kmax = if smoke { 5usize } else { 9 };
    for k in 3..=kmax {
        let n = 1usize << k;
        let a = 2usize;
        let dfs = verify_program(&pat::reduce_scatter_with(n, a, LinearOrder::DepthFirst))
            .unwrap()
            .peak_slots;
        let dm = verify_program(&pat::reduce_scatter_with(n, a, LinearOrder::DimMajor))
            .unwrap()
            .peak_slots;
        t.row([
            format!("{n}"),
            format!("{dfs}"),
            format!("{dm}"),
            format!("{:.1}x", dm as f64 / dfs as f64),
        ]);
        report.rows.push(Json::obj(vec![
            ("kind", Json::str("ordering_occupancy")),
            ("ranks", Json::num(n as f64)),
            ("dfs_slots", Json::num(dfs as f64)),
            ("dimmajor_slots", Json::num(dm as f64)),
        ]));
    }
    print!("{}", t.render());
    println!("depth-first is what makes the paper's bounded-buffer guarantee work.");

    // Same wire behaviour: step counts and simulated times match.
    let n = 64;
    let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
    let cost = CostModel::ib_hdr();
    let t_dfs = simulate(
        &pat::allgather_with(n, 2, LinearOrder::DepthFirst),
        &topo,
        &cost,
        4096,
    )
    .unwrap()
    .total_time;
    let t_dm = simulate(
        &pat::allgather_with(n, 2, LinearOrder::DimMajor),
        &topo,
        &cost,
        4096,
    )
    .unwrap()
    .total_time;
    println!(
        "wire time is order-independent: dfs {} vs dim-major {}\n",
        fmt_time_s(t_dfs),
        fmt_time_s(t_dm)
    );

    // --- ablation 2: the γ sweep ------------------------------------------
    println!("local per-chunk cost sweep (64 ranks, 4 KiB chunks, all-gather):");
    let mut t = Table::new(["gamma/chunk", "pat(full)", "pat:4", "ring", "best"]);
    let gammas: &[f64] = if smoke {
        &[0.0, 500.0]
    } else {
        &[0.0, 50.0, 500.0, 5000.0, 50000.0]
    };
    for &gamma_ns in gammas {
        let mut cost = CostModel::ib_hdr();
        cost.gamma_chunk = gamma_ns * 1e-9;
        let time = |alg: Algorithm| {
            let prog = sched::generate(alg, Collective::AllGather, n).unwrap();
            simulate(&prog, &topo, &cost, 4096).unwrap().total_time
        };
        let tp = time(Algorithm::Pat { aggregation: usize::MAX });
        let tp4 = time(Algorithm::Pat { aggregation: 4 });
        let tr = time(Algorithm::Ring);
        let best = if tp.min(tp4) < tr { "pat" } else { "ring" };
        t.row([
            format!("{gamma_ns} ns"),
            fmt_time_s(tp),
            fmt_time_s(tp4),
            fmt_time_s(tr),
            best.to_string(),
        ]);
        report.rows.push(Json::obj(vec![
            ("kind", Json::str("gamma_sweep")),
            ("gamma_ns", Json::num(gamma_ns)),
            ("pat_full", Json::num(tp)),
            ("pat_4", Json::num(tp4)),
            ("ring", Json::num(tr)),
        ]));
    }
    print!("{}", t.render());
    println!("as γ grows, PAT 'looks linear' and ring wins — the paper's caveat.");
    report.save().unwrap();
}
