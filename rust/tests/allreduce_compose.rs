//! Collective-composition invariants at scale: segment pipelining must pay
//! for itself in the simulator, and the phase overlap must be directly
//! observable from the per-step spans.

use patcol::core::{Algorithm, Collective, PhaseAlg, Placement};
use patcol::sched::compose::{self, Layout, Phase};
use patcol::sched::{self, verify::verify_program};
use patcol::sim::{simulate, CostModel, Topology};

/// 256-rank tapered three-level fat-tree (8 ranks/leaf, 4 leaves/pod,
/// top tier ×0.25) — the acceptance fabric.
fn tapered_256() -> Topology {
    Topology::three_level(256, 8, 4, 4, 2, CostModel::ib_hdr_nic_bw(), 1.0, 0.25).unwrap()
}

fn compose_prog(segments: usize, n: usize) -> patcol::sched::Program {
    let rs = PhaseAlg::Pat { aggregation: usize::MAX };
    let alg = Algorithm::Compose { rs, ag: rs, segments };
    sched::generate(alg, Collective::AllReduce, n).unwrap()
}

/// Pipelining pays off: at a small-to-mid payload (128 KiB per rank) on
/// the 256-rank tapered fat-tree, `pat+pat:4` completes strictly faster
/// than the sequential `pat+pat:1` at equal total payload — the four
/// segments run as independent channels whose messages fill each other's
/// link idle gaps and, with per-channel ECMP salts, spread over distinct
/// spines/cores. (At bandwidth-bound sizes the overlap gain fades and the
/// remaining advantage is the path spreading; the bench records the whole
/// sweep.)
#[test]
fn pipelined_beats_sequential_on_tapered_fabric() {
    let n = 256usize;
    let topo = tapered_256();
    let cost = CostModel::ib_hdr();
    // Equal total payload per rank (128 KiB): 512 B chunks at one segment
    // versus 128 B chunks across 4 segments.
    let chunk_seq = 512usize;
    let p1 = compose_prog(1, n);
    let p4 = compose_prog(4, n);
    let t1 = simulate(&p1, &topo, &cost, chunk_seq).unwrap().total_time;
    let t4 = simulate(&p4, &topo, &cost, chunk_seq / 4).unwrap().total_time;
    assert!(
        t4 < t1,
        "pat+pat:4 ({t4:.6}s) should beat pat+pat:1 ({t1:.6}s) at equal payload"
    );
}

/// The overlap is real, not just a step-numbering trick: segment 0's
/// all-gather window and segment 1's reduce-scatter window intersect in
/// simulated wall-clock time on the acceptance fabric.
#[test]
fn phase_windows_overlap_on_tapered_fabric() {
    let n = 256usize;
    let topo = tapered_256();
    let cost = CostModel::ib_hdr();
    let rs = sched::generate(
        Algorithm::Pat { aggregation: usize::MAX },
        Collective::ReduceScatter,
        n,
    )
    .unwrap();
    let ag = sched::generate(
        Algorithm::Pat { aggregation: usize::MAX },
        Collective::AllGather,
        n,
    )
    .unwrap();
    let fused = compose::fuse(&rs, &ag, 4).unwrap();
    let layout = Layout::of(&rs, &ag, 4);
    let rep = simulate(&fused, &topo, &cost, 4 << 10).unwrap();
    let windows = compose::phase_windows(&layout, &rep.step_spans);
    let get = |seg: usize, ph: Phase| {
        windows
            .iter()
            .find(|w| w.segment == seg && w.phase == ph)
            .unwrap_or_else(|| panic!("missing window for seg {seg} {ph:?}"))
    };
    for seg in 0..3 {
        let ag_w = get(seg, Phase::AllGather);
        let rs_w = get(seg + 1, Phase::ReduceScatter);
        assert!(
            ag_w.t_start < rs_w.t_end && rs_w.t_start < ag_w.t_end,
            "seg {seg}: ag=({}, {}) vs rs={seg_next}=({}, {}) do not overlap",
            ag_w.t_start,
            ag_w.t_end,
            rs_w.t_start,
            rs_w.t_end,
            seg_next = seg + 1,
        );
    }
}

/// Composed programs stay valid on placement-aware pairs over the
/// acceptance fabric's leaf-aligned placement, and the hierarchical phase
/// keeps its cross-leaf traffic advantage inside the composition.
#[test]
fn hier_phase_composes_on_tapered_fabric() {
    let n = 256usize;
    let topo = tapered_256();
    let pl = Placement::uniform(n, 8).unwrap();
    topo.check_placement(&pl).unwrap();
    let alg = Algorithm::Compose {
        rs: PhaseAlg::HierPat { aggregation: 4 },
        ag: PhaseAlg::HierPat { aggregation: 4 },
        segments: 2,
    };
    let hier = sched::generate_placed(alg, Collective::AllReduce, &pl).unwrap();
    verify_program(&hier).unwrap();
    let flat = compose_prog(2, n);
    let cost = CostModel::ib_hdr();
    let rep_hier = simulate(&hier, &topo, &cost, 2 << 10).unwrap();
    let rep_flat = simulate(&flat, &topo, &cost, 2 << 10).unwrap();
    let cross = |r: &patcol::sim::SimReport| r.msgs_by_level[1..].iter().sum::<usize>();
    assert!(
        cross(&rep_hier) < cross(&rep_flat),
        "hier pair should cross leaves less: {} !< {}",
        cross(&rep_hier),
        cross(&rep_flat)
    );
}
