//! Schedule generation: the PAT algorithm and its baselines, all emitting a
//! common per-rank program IR ([`Program`]).
//!
//! One IR serves every consumer in the stack:
//! * [`verify`] — the reference executor (correctness, FIFO/deadlock checks,
//!   buffer-occupancy measurement),
//! * [`crate::transport`] — the threaded real-byte engine,
//! * [`crate::sim`] — the event-driven network simulator,
//! * the schedule explorer example (regenerates the paper's figures).
//!
//! Reduce-scatter programs are derived from all-gather programs by
//! [`Program::mirror`]: reverse time, flip send↔recv, reduce on receive.
//! This is exactly the paper's construction ("the reduce-scatter PAT
//! algorithm works the same way as all-gather, but with a reversed binomial
//! tree", communicating close dimensions first and executing the parallel
//! trees before the logarithmic part).
//!
//! [`hier`] adds the topology-aware tier: two-level schedules over a rank
//! [`Placement`] (intra-node tree, inter-node PAT among node leaders,
//! intra-node fan-out) generated through the placement-aware front-end
//! [`generate_placed`].
//!
//! [`compose`] adds the collective-composition tier: all-reduce programs
//! fused from any reduce-scatter × any all-gather phase pair
//! ([`Algorithm::Compose`], spelled `rs+ag[:segments]`), with the payload
//! split into pipeline segments so one segment's all-gather overlaps the
//! next segment's reduce-scatter.
//!
//! [`channel`] adds the multi-channel tier: channels are a first-class
//! dimension of the IR ([`program::Op::channel`] — per-(rank, channel)
//! in-order streams, FIFO per (src, dst, channel)), and
//! [`channel::split`] shards *any* generated program across `C` channels
//! by chunk striping (spelled `alg*C`, e.g. `pat*4`). The composer's
//! pipeline segments are channels of the fused program, built on the same
//! FIFO-safe stream-merge machinery.
//!
//! [`bucket`] adds the multi-*operation* tier: a batch of back-to-back
//! all-reduce requests (gradient-bucket traffic; sizes, segment counts
//! and phase generators may differ per bucket) fuses into one program in
//! which bucket `i+1`'s reduce-scatter overlaps bucket `i`'s all-gather —
//! compose's segment stagger lifted across operations, with each bucket
//! on its own channels so concurrent buckets recruit parallel ECMP paths.

pub mod program;
pub mod tree;
pub mod ring;
pub mod bruck;
pub mod recursive;
pub mod pat;
pub mod hier;
pub mod compose;
pub mod channel;
pub mod bucket;
pub mod verify;
pub mod explain;

pub use program::{Op, Program, ProgramStats};
pub use tree::{FarFirstTree, NearFirstTree};
pub use verify::{verify_program, OccupancyReport};

use crate::core::{Algorithm, Collective, Error, PhaseAlg, Placement, Result};

/// Default node size assumed when a placement-aware algorithm is requested
/// without an explicit placement (contiguous 8-rank nodes — the common
/// GPUs-per-server count).
pub const DEFAULT_RANKS_PER_NODE: usize = 8;

/// Generate a program for `algorithm` on `nranks`.
///
/// For reduce-scatter, every algorithm is the mirror of its all-gather
/// counterpart (recursive doubling mirrors to recursive halving). For
/// all-reduce, [`Algorithm::Compose`] fuses its two phases
/// ([`compose::fuse`]); a non-composed algorithm is lifted to the
/// single-segment symmetric composition `alg+alg:1`. Placement-aware
/// algorithms ([`Algorithm::HierPat`], hierarchical compose phases) fall
/// back to contiguous nodes of [`DEFAULT_RANKS_PER_NODE`]; use
/// [`generate_placed`] to supply the real rank placement.
pub fn generate(alg: Algorithm, coll: Collective, nranks: usize) -> Result<Program> {
    if nranks == 0 {
        return Err(Error::Schedule("nranks must be >= 1".into()));
    }
    if alg.uses_placement() {
        let pl = Placement::uniform(nranks, DEFAULT_RANKS_PER_NODE)?;
        return generate_placed(alg, coll, &pl);
    }
    generate_inner(alg, coll, nranks, None)
}

/// Placement-aware generation front-end. [`Algorithm::HierPat`] (and
/// compose pairs with a hierarchical phase) build their two-level schedules
/// from `placement`; flat algorithms ignore it (their programs are
/// placement-oblivious by construction).
pub fn generate_placed(
    alg: Algorithm,
    coll: Collective,
    placement: &Placement,
) -> Result<Program> {
    let nranks = placement.nranks();
    if nranks == 0 {
        return Err(Error::Schedule("placement must cover >= 1 rank".into()));
    }
    generate_inner(alg, coll, nranks, Some(placement))
}

fn generate_inner(
    alg: Algorithm,
    coll: Collective,
    nranks: usize,
    placement: Option<&Placement>,
) -> Result<Program> {
    if !alg.supports(nranks) {
        return Err(Error::Unsupported(format!(
            "{alg} does not support nranks={nranks} (power-of-two required)"
        )));
    }
    if let Algorithm::Compose { rs, ag, segments } = alg {
        if coll != Collective::AllReduce {
            return Err(Error::Unsupported(format!(
                "{alg} composes an all-reduce; it cannot generate {coll}"
            )));
        }
        let rsp = generate_inner(rs.to_algorithm(), Collective::ReduceScatter, nranks, placement)?;
        let agp = generate_inner(ag.to_algorithm(), Collective::AllGather, nranks, placement)?;
        return compose::fuse(&rsp, &agp, segments);
    }
    if coll == Collective::AllReduce {
        // Lift a bare algorithm to the symmetric sequential composition.
        let ph = PhaseAlg::from_algorithm(alg)?;
        return generate_inner(
            Algorithm::Compose { rs: ph, ag: ph, segments: 1 },
            coll,
            nranks,
            placement,
        );
    }
    let ag = match alg {
        Algorithm::Ring => ring::allgather(nranks),
        Algorithm::BruckNearFirst => bruck::allgather_near_first(nranks),
        Algorithm::BruckFarFirst => bruck::allgather_far_first(nranks),
        Algorithm::Recursive => recursive::allgather(nranks),
        Algorithm::Pat { aggregation } => pat::allgather(nranks, aggregation),
        Algorithm::PatAuto => {
            return Err(Error::Schedule(
                "PatAuto must be resolved by the tuner before generation".into(),
            ))
        }
        Algorithm::HierPat { aggregation } => {
            let default_pl;
            let pl = match placement {
                Some(pl) => pl,
                None => {
                    default_pl = Placement::uniform(nranks, DEFAULT_RANKS_PER_NODE)?;
                    &default_pl
                }
            };
            hier::allgather(pl, aggregation)
        }
        Algorithm::Compose { .. } => unreachable!("handled above"),
    };
    Ok(match coll {
        Collective::AllGather => ag,
        Collective::ReduceScatter => ag.mirror(),
        Collective::AllReduce => unreachable!("handled above"),
    })
}
