//! Hierarchical vs flat PAT at scale on a tapered three-level fat-tree.
//!
//! The production question the `sched::hier` subsystem answers: once the
//! fabric's upper tiers are tapered and ranks are packed 8-to-a-leaf, how
//! much does running PAT *between nodes only* (leaders), with the chatty
//! phases kept under the leaf switches, buy over the flat schedule? This
//! bench sweeps 64–1024 simulated ranks at equal aggregation and reports
//! completion time plus the cross-leaf traffic metrics (messages and bytes
//! at fabric level ≥ 1) for both, emitting the usual JSON report.
//!
//! Two further sections feed the bench-baseline gate
//! ([`patcol::obs::baseline`]):
//!
//! * **Multi-leader striping** at 256 ranks and MiB+ sizes: `L` stripe
//!   leaders per node put `L` NICs and `L` distinct ECMP flows behind
//!   every node's inter-node traffic, and `L ≥ 2` must beat `L = 1`
//!   outright. Leader-staging high-water marks (reference executor) are
//!   stamped next to the analytic [`patcol::sched::hier::staging_bound`]
//!   so the gate can hold `hw ≤ bound` per leader count.
//! * **Three-level recursion** on the same fabric: a podded placement
//!   (leaf/pod/fabric) against the two-level schedule at the
//!   latency-relevant size, plus the hier Träff gap (`hier_gap_pct`) the
//!   gate holds to non-growth.

use patcol::core::{ceil_log2, Algorithm, Collective, Placement};
use patcol::report::Report;
use patcol::sched::{self, verify::verify_program};
use patcol::sim::{simulate, CostModel, SimReport, Topology};
use patcol::util::json::Json;
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};

fn cross_msgs(r: &SimReport) -> usize {
    r.msgs_by_level[1..].iter().sum()
}

fn cross_bytes(r: &SimReport) -> usize {
    r.bytes_by_level[1..].iter().sum()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ranks_per_leaf = 8usize;
    let leaves_per_pod = 4usize;
    let taper = 0.25f64;
    let chunk = 4 << 10; // latency-relevant size, the paper's PAT regime
    let agg = 4usize;
    let cost = CostModel::ib_hdr();

    let mut report = Report::new("hier_vs_flat");
    report.param("ranks_per_leaf", Json::num(ranks_per_leaf as f64));
    report.param("leaves_per_pod", Json::num(leaves_per_pod as f64));
    report.param("core_taper", Json::num(taper));
    report.param("chunk_bytes", Json::num(chunk as f64));
    report.param("aggregation", Json::num(agg as f64));

    println!(
        "\nall-gather, pat(a={agg}) vs hier_pat(a={agg}) on tapered three-level fat-trees \
         ({} per rank, top tier x{taper}):",
        fmt_bytes(chunk)
    );
    let mut t = Table::new([
        "ranks",
        "flat time",
        "hier time",
        "speedup",
        "flat x-leaf msgs",
        "hier x-leaf msgs",
        "flat x-leaf bytes",
        "hier x-leaf bytes",
    ]);

    let rank_sweep: &[usize] = if smoke {
        &[64]
    } else {
        &[64, 128, 256, 512, 1024]
    };
    for &n in rank_sweep {
        let topo = Topology::three_level(
            n,
            ranks_per_leaf,
            leaves_per_pod,
            4,
            2,
            CostModel::ib_hdr_nic_bw(),
            1.0,
            taper,
        )
        .unwrap();
        let pl = Placement::uniform(n, ranks_per_leaf).unwrap();
        topo.check_placement(&pl).unwrap();

        let flat_prog =
            sched::generate(Algorithm::Pat { aggregation: agg }, Collective::AllGather, n)
                .unwrap();
        let hier_prog = sched::generate_placed(
            Algorithm::HierPat { aggregation: agg },
            Collective::AllGather,
            &pl,
        )
        .unwrap();

        let flat = simulate(&flat_prog, &topo, &cost, chunk).unwrap();
        let hier = simulate(&hier_prog, &topo, &cost, chunk).unwrap();

        t.row([
            n.to_string(),
            fmt_time_s(flat.total_time),
            fmt_time_s(hier.total_time),
            format!("{:.2}x", flat.total_time / hier.total_time),
            cross_msgs(&flat).to_string(),
            cross_msgs(&hier).to_string(),
            fmt_bytes(cross_bytes(&flat)),
            fmt_bytes(cross_bytes(&hier)),
        ]);
        report.rows.push(Json::obj(vec![
            ("nranks", Json::num(n as f64)),
            ("flat_time", Json::num(flat.total_time)),
            ("hier_time", Json::num(hier.total_time)),
            ("flat_cross_msgs", Json::num(cross_msgs(&flat) as f64)),
            ("hier_cross_msgs", Json::num(cross_msgs(&hier) as f64)),
            ("flat_cross_bytes", Json::num(cross_bytes(&flat) as f64)),
            ("hier_cross_bytes", Json::num(cross_bytes(&hier) as f64)),
            ("flat_busiest_util", Json::num(flat.busiest_link_utilization)),
            ("hier_busiest_util", Json::num(hier.busiest_link_utilization)),
        ]));

        assert!(
            cross_msgs(&hier) < cross_msgs(&flat),
            "n={n}: hier must cross leaves less than flat"
        );
    }
    print!("{}", t.render());

    // ---- Multi-leader striping: 256 ranks, bandwidth-bound sizes ------
    //
    // The headline perf claim: L stripe leaders per node turn one leader
    // NIC into L parallel inter-node flows (distinct src ranks AND
    // distinct channel salts, so static ECMP spreads them over parallel
    // spines/cores). At MiB+ payloads L >= 2 must beat L = 1.
    let n = 256usize;
    let topo = Topology::three_level(
        n,
        ranks_per_leaf,
        leaves_per_pod,
        4,
        2,
        CostModel::ib_hdr_nic_bw(),
        1.0,
        taper,
    )
    .unwrap();
    let big_sizes: &[usize] = if smoke {
        &[1 << 20]
    } else {
        &[1 << 20, 4 << 20]
    };
    println!(
        "\nmulti-leader striping, hier_pat(a={agg}) on the {n}-rank tapered fat-tree:"
    );
    let mut t = Table::new(["chunk", "leaders", "time", "algbw", "staging hw", "bound"]);
    let mut time_by_l = std::collections::BTreeMap::new();
    for &bytes in big_sizes {
        for &l in &[1usize, 2, 4] {
            let pl = Placement::uniform(n, ranks_per_leaf)
                .unwrap()
                .with_leaders(l)
                .unwrap();
            topo.check_placement(&pl).unwrap();
            let prog = sched::generate_placed(
                Algorithm::HierPat { aggregation: agg },
                Collective::AllGather,
                &pl,
            )
            .unwrap();
            let rep = simulate(&prog, &topo, &cost, bytes).unwrap();
            let algbw = (n - 1) as f64 * bytes as f64 / rep.total_time;
            let hw = verify_program(&prog).unwrap().peak_slots;
            let bound = sched::hier::staging_bound(&pl, agg, Collective::AllGather);
            assert!(
                hw <= bound,
                "L={l}: staging high-water {hw} > bound {bound}"
            );
            t.row([
                fmt_bytes(bytes),
                l.to_string(),
                fmt_time_s(rep.total_time),
                format!("{}/s", fmt_bytes(algbw as usize)),
                hw.to_string(),
                bound.to_string(),
            ]);
            report.rows.push(Json::obj(vec![
                ("kind", Json::str("striping")),
                ("chunk_bytes", Json::num(bytes as f64)),
                ("leaders", Json::num(l as f64)),
                ("time", Json::num(rep.total_time)),
                ("algbw", Json::num(algbw)),
            ]));
            if bytes == big_sizes[0] {
                // occupancy is chunk-count-shaped: independent of bytes
                report.param(&format!("staging_hw_l{l}"), Json::num(hw as f64));
                report.param(&format!("staging_bound_l{l}"), Json::num(bound as f64));
            }
            time_by_l.insert((bytes, l), rep.total_time);
        }
        let (t1, t2) = (time_by_l[&(bytes, 1)], time_by_l[&(bytes, 2)]);
        assert!(
            t2 < t1,
            "{}: L=2 ({}) must beat L=1 ({})",
            fmt_bytes(bytes),
            fmt_time_s(t2),
            fmt_time_s(t1)
        );
    }
    print!("{}", t.render());

    // ---- Three-level recursion on the same fabric ---------------------
    //
    // Pods of `leaves_per_pod` nodes match the fabric's pod boundaries;
    // the recursion keeps pod-crossing traffic to pod leaders only.
    let pl2 = Placement::uniform(n, ranks_per_leaf).unwrap();
    let pl3 = pl2.clone().with_pods(leaves_per_pod).unwrap();
    topo.check_placement(&pl3).unwrap();
    let two = simulate(
        &sched::generate_placed(
            Algorithm::HierPat { aggregation: agg },
            Collective::AllGather,
            &pl2,
        )
        .unwrap(),
        &topo,
        &cost,
        chunk,
    )
    .unwrap();
    let three_prog = sched::generate_placed(
        Algorithm::HierPat { aggregation: agg },
        Collective::AllGather,
        &pl3,
    )
    .unwrap();
    let three = simulate(&three_prog, &topo, &cost, chunk).unwrap();
    let cross_pod = |r: &SimReport| r.bytes_by_level[2..].iter().sum::<usize>();
    println!(
        "\nthree-level recursion @ {}: two-level {} / three-level {} \
         (core-tier bytes {} -> {})",
        fmt_bytes(chunk),
        fmt_time_s(two.total_time),
        fmt_time_s(three.total_time),
        fmt_bytes(cross_pod(&two)),
        fmt_bytes(cross_pod(&three)),
    );
    assert!(
        cross_pod(&three) <= cross_pod(&two),
        "three-level recursion must not cross the core tier more than two-level"
    );
    report.rows.push(Json::obj(vec![
        ("kind", Json::str("three_level")),
        ("chunk_bytes", Json::num(chunk as f64)),
        ("two_level_time", Json::num(two.total_time)),
        ("three_level_time", Json::num(three.total_time)),
        ("two_level_core_bytes", Json::num(cross_pod(&two) as f64)),
        ("three_level_core_bytes", Json::num(cross_pod(&three) as f64)),
    ]));

    // Hier Träff gap at the headline latency config: modeled time over
    // the single-phase all-gather lower bound — max(⌈log2 n⌉ rounds,
    // (n−1)/n of the payload through one NIC). Deterministic
    // (simulator-derived), so the baseline gate can hold it to
    // non-growth like the latency_vs_size gaps.
    let nic = CostModel::ib_hdr_nic_bw();
    let bound = (ceil_log2(n) as f64 * cost.alpha_base)
        .max((n - 1) as f64 * chunk as f64 / nic);
    let gap = 100.0 * (two.total_time - bound) / bound.max(1e-30);
    println!("hier Träff gap @ {}: {gap:.1}%", fmt_bytes(chunk));
    report.param("hier_gap_pct", Json::num(gap));

    report.save().unwrap();
}
