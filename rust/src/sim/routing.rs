//! Static (deterministic) ECMP flow hashing.
//!
//! Real IB/RoCE fabrics pick the uplink for a flow from a hash of the flow
//! identifiers, fixed for the flow's lifetime ("static routing"). Two
//! simultaneous flows whose hashes collide share one uplink at half
//! bandwidth — the effect that makes the final steps of Bruck/recursive
//! doubling "run many times slower than the theory" (paper §1). The
//! simulator uses the same mechanism: the path for (src, dst) never changes
//! across steps or repetitions.

/// Deterministic 64-bit mix of (src, dst, salt) — splitmix64 finalizer over
/// the packed flow id.
#[inline]
pub fn flow_hash(src: u64, dst: u64, salt: u64) -> u64 {
    let mut z = src
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(dst.wrapping_mul(0xBF58476D1CE4E5B9))
        .wrapping_add(salt.wrapping_mul(0x94D049BB133111EB));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Measure ECMP collision pressure: given flows as (src, dst) pairs all
/// crossing the same `nports`-way choice point, return the maximum number
/// of flows hashed onto one port. Perfect spreading gives
/// `ceil(flows / nports)`; static hashing typically does worse — the
/// quantity the paper blames for Bruck's last-step slowdown.
pub fn max_port_collisions(flows: &[(usize, usize)], nports: usize, salt: u64) -> usize {
    let mut load = vec![0usize; nports.max(1)];
    for &(s, d) in flows {
        let p = (flow_hash(s as u64, d as u64, salt) % nports.max(1) as u64) as usize;
        load[p] += 1;
    }
    load.into_iter().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(flow_hash(3, 5, 0), flow_hash(3, 5, 0));
        assert_ne!(flow_hash(3, 5, 0), flow_hash(5, 3, 0));
    }

    #[test]
    fn spreads_reasonably() {
        // 1024 distinct flows over 16 ports: max load should be near 64,
        // certainly below 2x.
        let flows: Vec<(usize, usize)> = (0..1024).map(|i| (i, i + 7777)).collect();
        let m = max_port_collisions(&flows, 16, 0);
        assert!(m >= 64 && m < 128, "max load {m}");
    }

    #[test]
    fn collisions_exist_for_structured_flows() {
        // The Bruck last step: every rank i sends to i + n/2. With static
        // hashing, some uplink carries >= 2 of these flows for most salts —
        // demonstrating the paper's congestion mechanism.
        let n = 64;
        let flows: Vec<(usize, usize)> = (0..n / 2).map(|i| (i, i + n / 2)).collect();
        let m = max_port_collisions(&flows, n / 8, 1);
        assert!(m >= 2, "expected at least one collision, got max load {m}");
    }
}
