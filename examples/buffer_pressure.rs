//! Buffer pressure and the log→linear transition (paper Figs. 7–9).
//!
//! "As the size of the operation increases, we will reduce the size of the
//! logarithmic part and increase the size of the linear part." The
//! intermediate buffer is fixed in bytes; bigger chunks mean fewer chunks
//! fit, so the aggregation factor (number of parallel trees) shrinks:
//! 8 trees → 4 → 2 → fully linear.
//!
//!     cargo run --release --example buffer_pressure

use patcol::coordinator::Tuner;
use patcol::core::Collective;
use patcol::sched::{pat, verify::verify_program};
use patcol::sim::{simulate, CostModel, Topology};
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};

fn main() -> patcol::core::Result<()> {
    let n = 16;
    println!("PAT on {n} ranks: the aggregation sweep of Figs. 7-9\n");
    let mut t = Table::new([
        "trees(a)",
        "steps",
        "log",
        "linear",
        "rs_acc_slots",
        "sim 1KiB",
        "sim 1MiB",
    ]);
    let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
    let cost = CostModel::ib_hdr();
    for a in [8usize, 4, 2, 1] {
        let (log, lin) = pat::phase_counts(n, a);
        let ag = pat::allgather(n, a);
        let rs = pat::reduce_scatter(n, a);
        let occ = verify_program(&rs)?;
        let t_small = simulate(&ag, &topo, &cost, 1024)?.total_time;
        let t_big = simulate(&ag, &topo, &cost, 1 << 20)?.total_time;
        t.row([
            format!("{a}"),
            format!("{}", ag.steps),
            format!("{log}"),
            format!("{lin}"),
            format!("{}", occ.peak_slots),
            fmt_time_s(t_small),
            fmt_time_s(t_big),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nsteps 4/5/8/15 match Figs. 7/8/9/10; accumulator slots follow a*log2(n/a)\n"
    );

    // How a fixed buffer budget (in BYTES) translates to aggregation as the
    // message grows — the tuner's job.
    let buffer_bytes = 256 << 10; // 256 KiB intermediate buffer
    let tuner = Tuner::default();
    println!(
        "fixed {} intermediate buffer on {n} ranks (reduce-scatter):",
        fmt_bytes(buffer_bytes)
    );
    let mut t = Table::new(["chunk", "slots", "aggregation", "steps"]);
    for chunk in [1usize << 10, 8 << 10, 32 << 10, 128 << 10, 512 << 10] {
        let slots = (buffer_bytes / chunk).max(1);
        let a = tuner.max_aggregation(n, slots, Collective::ReduceScatter);
        let steps = pat::allgather(n, a).steps;
        t.row([
            fmt_bytes(chunk),
            format!("{slots}"),
            format!("{a}"),
            format!("{steps}"),
        ]);
    }
    print!("{}", t.render());
    println!("\nlarger chunks -> fewer slots -> fewer parallel trees -> more linear steps,");
    println!("each linear transfer running with a full buffer at peak bandwidth.");
    Ok(())
}
