//! PJRT datapath service, sharded.
//!
//! The `xla` crate's PJRT handles are `Rc`-based (not `Send`), so
//! dedicated service threads own the [`Registry`] clients and execute
//! reduction requests on behalf of all rank threads — the moral
//! equivalent of kernels serializing onto accelerator streams. The
//! service runs `shards` worker threads (one PJRT client each);
//! requests are routed by a `(rank, channel)` hash so one rank-channel
//! stream always lands on the same worker (preserving per-stream
//! ordering) while distinct streams spread across shards.
//!
//! The request ABI is slice-based: rank threads pass `(pointer, len)`
//! descriptors into buffers they own for the duration of the call and
//! block on a per-thread reply channel, so a reduction moves each
//! operand exactly once (the worker reads `x`, reads and writes `acc`)
//! instead of the old owned-`Vec` ABI's three full copies per call
//! (`acc.to_vec()`, `x.to_vec()`, `copy_from_slice` on reply). The
//! owned ABI survives as [`PjrtHandle::reduce_owned`] so the bench can
//! measure the gap.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::core::{Error, Result};
use crate::runtime::artifacts::Registry;
use crate::runtime::client::PjrtContext;
use crate::transport::datapath::{scalar_add, scalar_add_into};

/// A mutable slice descriptor that crosses the service channel. The
/// caller guarantees the buffer outlives the call (it blocks on the
/// reply before releasing the borrow).
#[derive(Clone, Copy)]
struct SlicePtr {
    ptr: *mut f32,
    len: usize,
}
// SAFETY: the pointed-to buffer is exclusively lent to the worker for
// the duration of one request; the caller blocks until the reply.
unsafe impl Send for SlicePtr {}

impl SlicePtr {
    fn of(s: &mut [f32]) -> SlicePtr {
        SlicePtr { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// # Safety
    ///
    /// Only callable while the originating borrow is still alive (the
    /// caller is blocked on the reply channel) and from at most one
    /// thread.
    unsafe fn slice<'a>(self) -> &'a mut [f32] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

/// Shared-slice counterpart of [`SlicePtr`].
#[derive(Clone, Copy)]
struct ConstSlicePtr {
    ptr: *const f32,
    len: usize,
}
// SAFETY: as for SlicePtr — lent for the duration of one request.
unsafe impl Send for ConstSlicePtr {}

impl ConstSlicePtr {
    fn of(s: &[f32]) -> ConstSlicePtr {
        ConstSlicePtr { ptr: s.as_ptr(), len: s.len() }
    }

    /// # Safety
    ///
    /// Only callable while the originating borrow is still alive (the
    /// caller is blocked on the reply channel).
    unsafe fn slice<'a>(self) -> &'a [f32] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

enum Request {
    /// Legacy owned ABI: acc += x elementwise; replies with the updated
    /// acc. Three copies per call — kept as the bench baseline.
    Reduce {
        acc: Vec<f32>,
        x: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    /// Zero-copy ABI: acc += x in place through slice descriptors.
    ReduceInPlace {
        acc: SlicePtr,
        x: ConstSlicePtr,
        reply: Sender<Result<()>>,
    },
    /// Zero-copy fused 3-operand form: out = a + b.
    AddInto {
        out: SlicePtr,
        a: ConstSlicePtr,
        b: ConstSlicePtr,
        reply: Sender<Result<()>>,
    },
    Shutdown,
}

/// What a worker thread reduces with.
enum Backend {
    /// Pure-rust lane-chunked kernel — lets the sharded slice ABI run
    /// (and be benchmarked) without PJRT artifacts.
    Scalar,
    /// The AOT Pallas kernels through a per-shard PJRT client.
    Registry(Registry),
}

impl Backend {
    fn reduce(&self, acc: &mut [f32], x: &[f32]) -> Result<()> {
        match self {
            Backend::Scalar => {
                scalar_add(acc, x);
                Ok(())
            }
            Backend::Registry(reg) => reg.reduce_f32(acc, x),
        }
    }

    fn add_into(&self, out: &mut [f32], a: &[f32], b: &[f32]) -> Result<()> {
        match self {
            Backend::Scalar => {
                scalar_add_into(out, a, b);
                Ok(())
            }
            Backend::Registry(reg) => {
                out.copy_from_slice(a);
                reg.reduce_f32(out, b)
            }
        }
    }
}

enum BackendSpec {
    Scalar,
    Artifacts(PathBuf),
}

thread_local! {
    /// Per-caller reply channel, reused across calls: the worker always
    /// replies exactly once per request before taking the next, so the
    /// receiver is fully drained between calls.
    static REPLY: (Sender<Result<()>>, Receiver<Result<()>>) = channel();
}

/// Cloneable, `Send` handle to the sharded PJRT service.
#[derive(Clone)]
pub struct PjrtHandle {
    txs: Arc<Vec<Sender<Request>>>,
}

impl PjrtHandle {
    fn shard(&self, rank: usize, channel: usize) -> &Sender<Request> {
        &self.txs[rank.wrapping_mul(31).wrapping_add(channel) % self.txs.len()]
    }

    fn call(&self, rank: usize, channel: usize, make: impl FnOnce(Sender<Result<()>>) -> Request) -> Result<()> {
        REPLY.with(|(tx, rx)| {
            self.shard(rank, channel)
                .send(make(tx.clone()))
                .map_err(|_| Error::Runtime("pjrt service is down".into()))?;
            rx.recv()
                .map_err(|_| Error::Runtime("pjrt service dropped reply".into()))?
        })
    }

    /// `acc += x` through the reduce kernel (shard 0).
    pub fn reduce_into(&self, acc: &mut [f32], x: &[f32]) -> Result<()> {
        self.reduce_into_routed(0, 0, acc, x)
    }

    /// `acc += x`, routed to the `(rank, channel)` shard. Zero-copy: the
    /// worker operates on the caller's buffers through the slice ABI.
    pub fn reduce_into_routed(
        &self,
        rank: usize,
        channel: usize,
        acc: &mut [f32],
        x: &[f32],
    ) -> Result<()> {
        let (accp, xp) = (SlicePtr::of(acc), ConstSlicePtr::of(x));
        self.call(rank, channel, |reply| Request::ReduceInPlace { acc: accp, x: xp, reply })
    }

    /// `out = a + b`, routed to the `(rank, channel)` shard — the fused
    /// 3-operand form: one read of each operand, one write.
    pub fn add_into_routed(
        &self,
        rank: usize,
        channel: usize,
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
    ) -> Result<()> {
        let (outp, ap, bp) = (SlicePtr::of(out), ConstSlicePtr::of(a), ConstSlicePtr::of(b));
        self.call(rank, channel, |reply| Request::AddInto { out: outp, a: ap, b: bp, reply })
    }

    /// The legacy owned-`Vec` ABI (shard 0): ships both operands by
    /// value and the result back. Three full copies per call — kept
    /// only so `benches/transport_hotpath.rs` can measure the slice
    /// ABI's gain against it.
    pub fn reduce_owned(&self, acc: Vec<f32>, x: Vec<f32>) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = channel();
        self.txs[0]
            .send(Request::Reduce { acc, x, reply: reply_tx })
            .map_err(|_| Error::Runtime("pjrt service is down".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt service dropped reply".into()))?
    }

    /// Number of service shards behind this handle.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }
}

/// Owns the service threads; dropping shuts them down.
pub struct PjrtService {
    txs: Vec<Sender<Request>>,
    joins: Vec<JoinHandle<()>>,
}

impl PjrtService {
    /// Spawn a single-shard service over the artifact directory (must
    /// contain `manifest.json`; see `make artifacts`). Fails fast if the
    /// registry cannot be loaded.
    pub fn spawn(artifact_dir: PathBuf) -> Result<(PjrtService, PjrtHandle)> {
        Self::spawn_sharded(artifact_dir, 1)
    }

    /// Spawn `shards` service threads, each owning its own PJRT client
    /// over the artifact directory.
    pub fn spawn_sharded(artifact_dir: PathBuf, shards: usize) -> Result<(PjrtService, PjrtHandle)> {
        Self::spawn_workers(BackendSpec::Artifacts(artifact_dir), shards)
    }

    /// Spawn `shards` service threads over the pure-rust scalar backend
    /// — the sharded slice ABI without PJRT artifacts (bench/CI path).
    pub fn spawn_scalar(shards: usize) -> Result<(PjrtService, PjrtHandle)> {
        Self::spawn_workers(BackendSpec::Scalar, shards)
    }

    fn spawn_workers(spec: BackendSpec, shards: usize) -> Result<(PjrtService, PjrtHandle)> {
        let shards = shards.max(1);
        let mut txs = Vec::with_capacity(shards);
        let mut joins = Vec::with_capacity(shards);
        let mut readies = Vec::with_capacity(shards);
        for i in 0..shards {
            let (tx, rx) = channel::<Request>();
            let (ready_tx, ready_rx) = channel::<Result<()>>();
            let dir = match &spec {
                BackendSpec::Scalar => None,
                BackendSpec::Artifacts(d) => Some(d.clone()),
            };
            let join = std::thread::Builder::new()
                .name(format!("pjrt-service-{i}"))
                .spawn(move || {
                    let backend = match dir {
                        None => {
                            let _ = ready_tx.send(Ok(()));
                            Backend::Scalar
                        }
                        Some(dir) => match PjrtContext::cpu()
                            .and_then(|ctx| Registry::load(ctx, &dir))
                        {
                            Ok(r) => {
                                let _ = ready_tx.send(Ok(()));
                                Backend::Registry(r)
                            }
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        },
                    };
                    while let Ok(req) = rx.recv() {
                        match req {
                            Request::Reduce { mut acc, x, reply } => {
                                let res = backend.reduce(&mut acc, &x).map(|()| acc);
                                let _ = reply.send(res);
                            }
                            Request::ReduceInPlace { acc, x, reply } => {
                                // SAFETY: the caller blocks on `reply`
                                // with both borrows alive until we send.
                                let res = unsafe { backend.reduce(acc.slice(), x.slice()) };
                                let _ = reply.send(res);
                            }
                            Request::AddInto { out, a, b, reply } => {
                                // SAFETY: as above — exclusive lease
                                // until the reply is sent.
                                let res =
                                    unsafe { backend.add_into(out.slice(), a.slice(), b.slice()) };
                                let _ = reply.send(res);
                            }
                            Request::Shutdown => break,
                        }
                    }
                })
                .map_err(|e| Error::Runtime(format!("spawn pjrt service: {e}")))?;
            joins.push(join);
            txs.push(tx);
            readies.push(ready_rx);
        }
        // Wait for every worker to come up (or fail fast on the first
        // startup error — remaining workers are shut down by Drop of the
        // partially-built service's channels going out of scope).
        for ready_rx in readies {
            ready_rx
                .recv()
                .map_err(|_| Error::Runtime("pjrt service died during startup".into()))??;
        }
        let service = PjrtService { txs: txs.clone(), joins };
        let handle = PjrtHandle { txs: Arc::new(txs) };
        Ok((service, handle))
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Request::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Default reduction-shard count: `min(cores, ranks)`, at least one.
pub fn default_reduce_shards(nranks: usize) -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(nranks.max(1))
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Startup failure surfaces as a clean error: either the registry
    /// pointer ("make artifacts") with a real backend, or the stub's
    /// backend-unavailable message.
    #[test]
    fn startup_failure_is_reported() {
        let err = PjrtService::spawn(PathBuf::from("/nonexistent")).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("make artifacts") || msg.contains("unavailable"),
            "{msg}"
        );
    }

    /// The sharded scalar backend serves all three request forms, and
    /// routing spreads streams without breaking results.
    #[test]
    fn scalar_shards_reduce_and_add() {
        let (_svc, h) = PjrtService::spawn_scalar(3).unwrap();
        assert_eq!(h.shards(), 3);
        let mut acc = vec![1.0f32; 64];
        h.reduce_into_routed(2, 1, &mut acc, &[4.0; 64]).unwrap();
        assert!(acc.iter().all(|&v| v == 5.0));
        let mut out = vec![0.0f32; 33];
        h.add_into_routed(5, 0, &mut out, &[2.0; 33], &[3.0; 33]).unwrap();
        assert!(out.iter().all(|&v| v == 5.0));
        // the legacy owned ABI still answers (bench baseline)
        let res = h.reduce_owned(vec![1.0; 16], vec![2.0; 16]).unwrap();
        assert!(res.iter().all(|&v| v == 3.0));
        // many routed calls across shards stay correct
        for r in 0..16usize {
            let mut a = vec![r as f32; 8];
            h.reduce_into_routed(r, r % 4, &mut a, &[1.0; 8]).unwrap();
            assert!(a.iter().all(|&v| v == r as f32 + 1.0));
        }
    }

    #[test]
    fn default_shards_bounded_by_ranks() {
        assert_eq!(default_reduce_shards(1), 1);
        assert!(default_reduce_shards(64) >= 1);
        assert!(default_reduce_shards(2) <= 2);
        // nranks = 0 still yields a valid shard count
        assert_eq!(default_reduce_shards(0), 1);
    }
}

impl std::fmt::Debug for PjrtHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PjrtHandle({} shards)", self.txs.len())
    }
}

impl std::fmt::Debug for PjrtService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PjrtService({} shards)", self.txs.len())
    }
}
