//! Reference executor over the program IR: correctness, per-channel FIFO
//! matching, deadlock detection, and buffer-occupancy measurement.
//!
//! This is the ground truth every generator, the channel splitter, the
//! transport engine, and the simulator are validated against.
//! Reduce-scatter is checked with exact integer arithmetic (each rank's
//! contribution to each chunk is a distinct integer), so reduction-order
//! questions cannot mask a miscounted or double-counted contribution.
//!
//! Channels: messages match FIFO per **(src, dst, channel)** — each
//! channel is its own connection (see [`crate::sched::channel`]). The
//! executor runs each rank's merged op list as one stream, which is
//! *stricter* than the per-channel executors (transport/sim): a program
//! that passes here is executable by them, because the merged order is a
//! valid linear extension of every channel's order. Occupancy is counted
//! across all of a rank's channels together — the physical staging buffer
//! is shared. Chunk ownership is `id % nranks` throughout, so
//! multi-channel (striped), composed, and bucketed
//! ([`crate::sched::bucket`] — a batch of all-reduces over one
//! concatenated chunk space) programs verify through the same code as
//! the primitive `nranks`-chunk programs: per-bucket reduction
//! correctness *is* per-chunk exactness over the concatenation.

use std::collections::{HashMap, VecDeque};

use crate::core::{ChunkId, Collective, Error, Rank, Result};
use crate::sched::program::{Op, Program};

/// Buffer-occupancy report (paper claim P3: PAT needs a logarithmic amount
/// of internal buffering, independent of the operation size).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OccupancyReport {
    /// All-gather: peak number of chunks held in staging (received but not
    /// yet fully forwarded, excluding the rank's own chunks) on any rank.
    /// Reduce-scatter: peak number of live accumulators on any rank.
    /// All-reduce: peak of live accumulators plus staged (received, not yet
    /// fully rebroadcast) final chunks on any rank — the bound the fused
    /// program's staging slots must cover across both phases. Counted
    /// across all of a rank's channels together: the physical staging
    /// buffer is shared, so a C-channel split peaks at up to C× the
    /// single-channel bound (in C×-smaller chunks).
    pub peak_slots: usize,
    /// Rank on which the peak occurred.
    pub peak_rank: Rank,
}

/// The exact integer contribution of `rank` to `chunk` used by the
/// reduce-scatter check (distinct per (rank, chunk) pair).
pub fn rs_contribution(rank: Rank, chunk: ChunkId) -> i64 {
    (rank as i64 + 1) * 1_000_003 + (chunk as i64 + 1) * 7919
}

/// Verify a program end-to-end. Checks, in order:
/// 1. per-(src, dst, channel) FIFO consistency (k-th recv on a connection
///    matches its k-th send: same chunk list, matching reduce flag for the
///    collective),
/// 2. deadlock-free completion under blocking receives,
/// 3. data correctness (every rank owns every chunk for AG; exact reduced
///    sums on the owner rank for RS; every rank ends with the full sum of
///    every rank's contribution for all-reduce),
/// 4. causality (a rank only sends chunk data it actually holds).
///
/// Returns the buffer-occupancy report measured during execution.
pub fn verify_program(p: &Program) -> Result<OccupancyReport> {
    check_fifo(p)?;
    match p.collective {
        Collective::AllGather => verify_allgather(p),
        Collective::ReduceScatter => verify_reduce_scatter(p),
        Collective::AllReduce => verify_allreduce(p),
    }
}

/// Structural FIFO check: for each connection (s, d, channel), the
/// sequence of sends s→d on the channel equals the sequence of recvs at d
/// from s on that channel (chunk lists in order), and reduce flags agree
/// with the collective type (all-reduce programs mix both kinds: reducing
/// receives in the reduce-scatter phase, plain receives in the rebroadcast
/// phase). A send and recv whose channels disagree surface here as
/// mismatched connection sequences.
pub fn check_fifo(p: &Program) -> Result<()> {
    let mut sends: HashMap<(Rank, Rank, usize), Vec<&Vec<ChunkId>>> = HashMap::new();
    let mut recvs: HashMap<(Rank, Rank, usize), Vec<&Vec<ChunkId>>> = HashMap::new();
    for (r, ops) in p.ranks.iter().enumerate() {
        for op in ops {
            match op {
                Op::Send { peer, chunks, channel, .. } => {
                    if *peer == r {
                        return Err(Error::Verify(format!("rank {r} sends to itself")));
                    }
                    sends.entry((r, *peer, *channel)).or_default().push(chunks);
                }
                Op::Recv { peer, chunks, reduce, channel, .. } => {
                    let bad = match p.collective {
                        Collective::AllGather => *reduce,
                        Collective::ReduceScatter => !*reduce,
                        Collective::AllReduce => false,
                    };
                    if bad {
                        return Err(Error::Verify(format!(
                            "rank {r}: recv reduce={reduce} inconsistent with {}",
                            p.collective
                        )));
                    }
                    recvs.entry((*peer, r, *channel)).or_default().push(chunks);
                }
            }
        }
    }
    for (conn, s) in &sends {
        let r = recvs.get(conn).map(|v| v.as_slice()).unwrap_or(&[]);
        if s.len() != r.len() {
            return Err(Error::Verify(format!(
                "connection {conn:?} (src, dst, channel): {} sends vs {} recvs",
                s.len(),
                r.len()
            )));
        }
        for (k, (sc, rc)) in s.iter().zip(r.iter()).enumerate() {
            if sc != rc {
                return Err(Error::Verify(format!(
                    "connection {conn:?} message {k}: send chunks {sc:?} != recv chunks {rc:?}"
                )));
            }
        }
    }
    for conn in recvs.keys() {
        if !sends.contains_key(conn) {
            return Err(Error::Verify(format!(
                "recv with no send for connection {conn:?} (src, dst, channel)"
            )));
        }
    }
    Ok(())
}

/// Round-robin execution harness shared by both verifiers. Calls `on_send`
/// / `on_recv` as ops retire; returns an error on deadlock.
fn execute<FS, FR>(p: &Program, mut on_send: FS, mut on_recv: FR) -> Result<()>
where
    FS: FnMut(Rank, Rank, &[ChunkId]) -> Result<Vec<i64>>,
    FR: FnMut(Rank, Rank, &[ChunkId], bool, Vec<i64>) -> Result<()>,
{
    let n = p.nranks;
    let mut pc = vec![0usize; n];
    // In-flight FIFO queues per connection (src, dst, channel).
    let mut wires: HashMap<(Rank, Rank, usize), VecDeque<Vec<i64>>> = HashMap::new();
    loop {
        let mut progressed = false;
        let mut all_done = true;
        for r in 0..n {
            // Drain every op the rank can retire right now (sends always
            // retire; recvs retire when the message is queued).
            while pc[r] < p.ranks[r].len() {
                match &p.ranks[r][pc[r]] {
                    Op::Send { peer, chunks, channel, .. } => {
                        let payload = on_send(r, *peer, chunks)?;
                        wires.entry((r, *peer, *channel)).or_default().push_back(payload);
                        pc[r] += 1;
                        progressed = true;
                    }
                    Op::Recv { peer, chunks, reduce, channel, .. } => {
                        let q = wires.entry((*peer, r, *channel)).or_default();
                        if let Some(payload) = q.pop_front() {
                            on_recv(r, *peer, chunks, *reduce, payload)?;
                            pc[r] += 1;
                            progressed = true;
                        } else {
                            break;
                        }
                    }
                }
            }
            if pc[r] < p.ranks[r].len() {
                all_done = false;
            }
        }
        if all_done {
            return Ok(());
        }
        if !progressed {
            let stuck: Vec<String> = (0..n)
                .filter(|&r| pc[r] < p.ranks[r].len())
                .map(|r| format!("rank {r} at op {}: {:?}", pc[r], p.ranks[r][pc[r]]))
                .collect();
            return Err(Error::Verify(format!(
                "deadlock; blocked ranks: {}",
                stuck.join("; ")
            )));
        }
    }
}

fn verify_allgather(p: &Program) -> Result<OccupancyReport> {
    let n = p.nranks;
    // Chunk space: `n` for the primitive programs, `C·n` for channel-split
    // ones (stripe k renames chunk c to k·n + c); ownership is id mod n.
    let nchunks = p.chunk_space();
    // owned[r][c]: value of chunk c held by rank r (i64 tag), or None.
    let mut owned: Vec<Vec<Option<i64>>> = (0..n)
        .map(|r| {
            (0..nchunks)
                .map(|c| if c % n == r { Some(chunk_tag(c)) } else { None })
                .collect()
        })
        .collect();
    // Staging occupancy: chunks received that still have pending forwards.
    // pending_forwards[r][c] = number of sends of chunk c by rank r that
    // occur *after* its receive, computed statically.
    let pending = pending_forwards(p);
    let mut live: Vec<HashMap<ChunkId, usize>> = vec![HashMap::new(); n];
    let mut peak = OccupancyReport { peak_slots: 0, peak_rank: 0 };

    // Work around borrow rules: state in RefCell-free closures via split.
    let owned_cell = std::cell::RefCell::new(&mut owned);
    let live_cell = std::cell::RefCell::new(&mut live);
    let peak_cell = std::cell::RefCell::new(&mut peak);

    execute(
        p,
        |r, _dst, chunks| {
            let ow = owned_cell.borrow_mut();
            let mut lv = live_cell.borrow_mut();
            let mut payload = Vec::with_capacity(chunks.len());
            for &c in chunks {
                let v = ow[r][c].ok_or_else(|| {
                    Error::Verify(format!("rank {r} sends chunk {c} it does not hold"))
                })?;
                payload.push(v);
                // Retire one pending forward; free the staging slot on last.
                if let Some(cnt) = lv[r].get_mut(&c) {
                    *cnt -= 1;
                    if *cnt == 0 {
                        lv[r].remove(&c);
                    }
                }
            }
            Ok(payload)
        },
        |r, _src, chunks, _reduce, payload| {
            let mut ow = owned_cell.borrow_mut();
            let mut lv = live_cell.borrow_mut();
            let mut pk = peak_cell.borrow_mut();
            if payload.len() != chunks.len() {
                return Err(Error::Verify("payload/chunks length mismatch".into()));
            }
            for (&c, v) in chunks.iter().zip(payload) {
                if ow[r][c].is_some() {
                    return Err(Error::Verify(format!(
                        "rank {r} received chunk {c} it already holds"
                    )));
                }
                if v != chunk_tag(c) {
                    return Err(Error::Verify(format!(
                        "rank {r} chunk {c}: corrupted tag {v}"
                    )));
                }
                ow[r][c] = Some(v);
                let fw = pending[r].get(&c).copied().unwrap_or(0);
                if fw > 0 {
                    lv[r].insert(c, fw);
                }
            }
            if lv[r].len() > pk.peak_slots {
                pk.peak_slots = lv[r].len();
                pk.peak_rank = r;
            }
            Ok(())
        },
    )?;

    for (r, row) in owned.iter().enumerate() {
        for (c, v) in row.iter().enumerate() {
            if v.is_none() {
                return Err(Error::Verify(format!(
                    "all-gather incomplete: rank {r} missing chunk {c}"
                )));
            }
        }
    }
    Ok(peak)
}

/// For each rank, how many times each chunk is forwarded after being
/// received (all-gather staging lifetime).
fn pending_forwards(p: &Program) -> Vec<HashMap<ChunkId, usize>> {
    let mut out: Vec<HashMap<ChunkId, usize>> = vec![HashMap::new(); p.nranks];
    for (r, ops) in p.ranks.iter().enumerate() {
        let mut seen_recv: HashMap<ChunkId, bool> = HashMap::new();
        for op in ops {
            match op {
                Op::Recv { chunks, .. } => {
                    for &c in chunks {
                        seen_recv.insert(c, true);
                    }
                }
                Op::Send { chunks, .. } => {
                    for &c in chunks {
                        if seen_recv.get(&c).copied().unwrap_or(false) {
                            *out[r].entry(c).or_insert(0) += 1;
                        }
                    }
                }
            }
        }
    }
    out
}

fn chunk_tag(c: ChunkId) -> i64 {
    (c as i64 + 1) * 104_729
}

fn verify_reduce_scatter(p: &Program) -> Result<OccupancyReport> {
    let n = p.nranks;
    // Chunk space as for all-gather: rank r's output chunks are those with
    // `c % n == r` (one per channel stripe).
    let nchunks = p.chunk_space();
    // Accumulators per rank: chunk -> partial sum. Own contribution is
    // consumed exactly when the chunk is sent (or at completion for the
    // rank's own chunks).
    let mut acc: Vec<HashMap<ChunkId, i64>> = vec![HashMap::new(); n];
    let mut contributed: Vec<Vec<bool>> = vec![vec![false; nchunks]; n];
    let mut peak = OccupancyReport { peak_slots: 0, peak_rank: 0 };

    let acc_cell = std::cell::RefCell::new(&mut acc);
    let contrib_cell = std::cell::RefCell::new(&mut contributed);
    let peak_cell = std::cell::RefCell::new(&mut peak);

    execute(
        p,
        |r, _dst, chunks| {
            let mut ac = acc_cell.borrow_mut();
            let mut ct = contrib_cell.borrow_mut();
            let mut payload = Vec::with_capacity(chunks.len());
            for &c in chunks {
                if c % n == r {
                    return Err(Error::Verify(format!(
                        "rank {r} sends its own output chunk {c}"
                    )));
                }
                if ct[r][c] {
                    return Err(Error::Verify(format!(
                        "rank {r} contributes to chunk {c} twice"
                    )));
                }
                ct[r][c] = true;
                let partial = ac[r].remove(&c).unwrap_or(0);
                payload.push(partial + rs_contribution(r, c));
            }
            Ok(payload)
        },
        |r, _src, chunks, _reduce, payload| {
            let mut ac = acc_cell.borrow_mut();
            let mut pk = peak_cell.borrow_mut();
            for (&c, v) in chunks.iter().zip(payload) {
                *ac[r].entry(c).or_insert(0) += v;
            }
            if ac[r].len() > pk.peak_slots {
                pk.peak_slots = ac[r].len();
                pk.peak_rank = r;
            }
            Ok(())
        },
    )?;

    // Completion: rank r holds exactly the full sum for each of its own
    // chunks (one per channel stripe).
    for r in 0..n {
        for c in (0..nchunks).filter(|c| c % n == r) {
            let own = acc[r].remove(&c).unwrap_or(0) + rs_contribution(r, c);
            let want: i64 = (0..n).map(|i| rs_contribution(i, c)).sum();
            if own != want {
                return Err(Error::Verify(format!(
                    "reduce-scatter: rank {r} chunk {c} output {own} != expected {want}"
                )));
            }
        }
        if !acc[r].is_empty() {
            return Err(Error::Verify(format!(
                "rank {r} left with stale accumulators for chunks {:?}",
                acc[r].keys().collect::<Vec<_>>()
            )));
        }
        // Every rank must have contributed to every chunk exactly once
        // (either by sending it or by owning the output).
        for c in 0..nchunks {
            if c % n != r && !contributed[r][c] {
                return Err(Error::Verify(format!(
                    "rank {r} never contributed to chunk {c}"
                )));
            }
        }
    }
    Ok(peak)
}

/// All-reduce reference semantics: every rank contributes
/// [`rs_contribution`]`(rank, chunk)` to every chunk; chunk `c` is owned by
/// rank `c mod nranks` (the composed chunk renaming of
/// [`crate::sched::compose`]); at completion every rank must hold, for
/// every chunk, the exact sum of all contributions.
///
/// Execution model per (rank, chunk):
/// * a **reducing recv** folds a partial sum into the rank's accumulator
///   (reduce-scatter phase);
/// * a **send** of a chunk the rank has no final value for pays the rank's
///   own contribution (exactly once) plus any accumulator — the
///   reduce-scatter contribute-and-forward. The *owner's* first such send
///   completes the reduction and doubles as the start of the rebroadcast.
/// * a **plain recv** installs the final value (checked against the exact
///   expected sum on the spot, so an owner that rebroadcasts before all
///   contributions arrived fails loudly);
/// * later sends of a finalized chunk are relays of the final value.
///
/// Occupancy counts live accumulators plus staged finals (received but not
/// yet fully re-forwarded) — the two-phase buffer footprint the transport's
/// staging slots must cover.
fn verify_allreduce(p: &Program) -> Result<OccupancyReport> {
    let n = p.nranks;
    let nchunks = p.chunk_space();
    // Expected full sums, precomputed once per chunk (the rebroadcast
    // check runs per received chunk — O(S·n²) installs).
    let want: Vec<i64> = (0..nchunks)
        .map(|c| (0..n).map(|i| rs_contribution(i, c)).sum())
        .collect();

    // acc[r]: chunk -> partial sum. fin[r]: chunk -> final value.
    let mut acc: Vec<HashMap<ChunkId, i64>> = vec![HashMap::new(); n];
    let mut fin: Vec<HashMap<ChunkId, i64>> = vec![HashMap::new(); n];
    let mut contributed: Vec<HashMap<ChunkId, bool>> = vec![HashMap::new(); n];
    // Staging lifetime of rebroadcast finals: sends of a chunk occurring
    // after its plain recv, computed statically per rank.
    let pending = pending_rebroadcasts(p);
    let mut live: Vec<HashMap<ChunkId, usize>> = vec![HashMap::new(); n];
    let mut peak = OccupancyReport { peak_slots: 0, peak_rank: 0 };

    let acc_cell = std::cell::RefCell::new(&mut acc);
    let fin_cell = std::cell::RefCell::new(&mut fin);
    let contrib_cell = std::cell::RefCell::new(&mut contributed);
    let live_cell = std::cell::RefCell::new(&mut live);
    let peak_cell = std::cell::RefCell::new(&mut peak);

    execute(
        p,
        |r, _dst, chunks| {
            let mut ac = acc_cell.borrow_mut();
            let mut fi = fin_cell.borrow_mut();
            let mut ct = contrib_cell.borrow_mut();
            let mut lv = live_cell.borrow_mut();
            let mut payload = Vec::with_capacity(chunks.len());
            for &c in chunks {
                if let Some(&v) = fi[r].get(&c) {
                    // Relay of an already-final chunk (all-gather phase).
                    payload.push(v);
                    if let Some(cnt) = lv[r].get_mut(&c) {
                        *cnt -= 1;
                        if *cnt == 0 {
                            lv[r].remove(&c);
                        }
                    }
                    continue;
                }
                if *ct[r].entry(c).or_insert(false) {
                    return Err(Error::Verify(format!(
                        "rank {r} contributes to chunk {c} twice"
                    )));
                }
                ct[r].insert(c, true);
                let v = ac[r].remove(&c).unwrap_or(0) + rs_contribution(r, c);
                if c % n == r {
                    // Owner: this send completes the reduction and starts
                    // the rebroadcast.
                    fi[r].insert(c, v);
                }
                payload.push(v);
            }
            Ok(payload)
        },
        |r, _src, chunks, reduce, payload| {
            let mut ac = acc_cell.borrow_mut();
            let mut fi = fin_cell.borrow_mut();
            let mut lv = live_cell.borrow_mut();
            let mut pk = peak_cell.borrow_mut();
            if payload.len() != chunks.len() {
                return Err(Error::Verify("payload/chunks length mismatch".into()));
            }
            for (&c, v) in chunks.iter().zip(payload) {
                if reduce {
                    if fi[r].contains_key(&c) {
                        return Err(Error::Verify(format!(
                            "rank {r}: reducing recv of chunk {c} after it was finalized"
                        )));
                    }
                    *ac[r].entry(c).or_insert(0) += v;
                } else {
                    if v != want[c] {
                        return Err(Error::Verify(format!(
                            "rank {r} chunk {c}: rebroadcast value {v} != full sum {} \
                             (owner rebroadcast before all contributions arrived?)",
                            want[c]
                        )));
                    }
                    if fi[r].insert(c, v).is_some() {
                        return Err(Error::Verify(format!(
                            "rank {r} received final chunk {c} twice"
                        )));
                    }
                    let fw = pending[r].get(&c).copied().unwrap_or(0);
                    if fw > 0 {
                        lv[r].insert(c, fw);
                    }
                }
            }
            let occ = ac[r].len() + lv[r].len();
            if occ > pk.peak_slots {
                pk.peak_slots = occ;
                pk.peak_rank = r;
            }
            Ok(())
        },
    )?;

    for r in 0..n {
        for c in 0..nchunks {
            let got = match fin[r].get(&c) {
                Some(&v) => v,
                // An owner that never rebroadcast (n == 1, opless ranks)
                // finalizes locally at completion.
                None if c % n == r => {
                    acc[r].remove(&c).unwrap_or(0) + rs_contribution(r, c)
                }
                None => {
                    return Err(Error::Verify(format!(
                        "all-reduce incomplete: rank {r} missing final chunk {c}"
                    )))
                }
            };
            if got != want[c] {
                return Err(Error::Verify(format!(
                    "all-reduce: rank {r} chunk {c} = {got} != expected {}",
                    want[c]
                )));
            }
        }
        // Non-own accumulators must all have been consumed by sends.
        if let Some(c) = acc[r].keys().next() {
            return Err(Error::Verify(format!(
                "rank {r} left with a stale accumulator for chunk {c}"
            )));
        }
    }
    Ok(peak)
}

/// For each rank, how many times each chunk is sent after its plain
/// (non-reducing) recv — the all-reduce rebroadcast staging lifetime.
fn pending_rebroadcasts(p: &Program) -> Vec<HashMap<ChunkId, usize>> {
    let mut out: Vec<HashMap<ChunkId, usize>> = vec![HashMap::new(); p.nranks];
    for (r, ops) in p.ranks.iter().enumerate() {
        let mut seen_final: HashMap<ChunkId, bool> = HashMap::new();
        for op in ops {
            match op {
                Op::Recv { chunks, reduce: false, .. } => {
                    for &c in chunks {
                        seen_final.insert(c, true);
                    }
                }
                Op::Recv { .. } => {}
                Op::Send { chunks, .. } => {
                    for &c in chunks {
                        if seen_final.get(&c).copied().unwrap_or(false) {
                            *out[r].entry(c).or_insert(0) += 1;
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::program::{Op, Program};

    fn push_pair(p: &mut Program, src: Rank, dst: Rank, chunks: Vec<ChunkId>, step: usize) {
        let reduce = p.collective == Collective::ReduceScatter;
        p.push(src, Op::send(dst, chunks.clone(), step));
        p.push(dst, Op::recv(src, chunks, reduce, step));
    }

    #[test]
    fn detects_missing_chunk() {
        // 3 ranks, rank 2 never receives chunk 0.
        let mut p = Program::new(3, Collective::AllGather, "bad");
        push_pair(&mut p, 0, 1, vec![0], 0);
        push_pair(&mut p, 1, 0, vec![1], 0);
        push_pair(&mut p, 1, 2, vec![1], 1);
        push_pair(&mut p, 2, 0, vec![2], 1);
        push_pair(&mut p, 2, 1, vec![2], 1);
        let err = verify_program(&p).unwrap_err();
        assert!(err.to_string().contains("missing chunk"), "{err}");
    }

    #[test]
    fn detects_send_of_unheld_chunk() {
        let mut p = Program::new(2, Collective::AllGather, "bad");
        // rank 0 sends chunk 1 which it does not hold.
        push_pair(&mut p, 0, 1, vec![1], 0);
        push_pair(&mut p, 1, 0, vec![1], 0);
        let err = verify_program(&p).unwrap_err();
        assert!(err.to_string().contains("does not hold"), "{err}");
    }

    #[test]
    fn detects_deadlock() {
        let mut p = Program::new(2, Collective::AllGather, "bad");
        // Both ranks recv first from each other with no sends queued.
        p.push(0, Op::recv(1, vec![1], false, 0));
        p.push(0, Op::send(1, vec![0], 0));
        p.push(1, Op::recv(0, vec![0], false, 0));
        p.push(1, Op::send(0, vec![1], 0));
        let err = verify_program(&p).unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }

    #[test]
    fn detects_fifo_mismatch() {
        let mut p = Program::new(2, Collective::AllGather, "bad");
        p.push(0, Op::send(1, vec![0], 0));
        p.push(1, Op::recv(0, vec![1], false, 0));
        let err = verify_program(&p).unwrap_err();
        assert!(err.to_string().contains("send chunks"), "{err}");
    }

    /// A send and recv that agree on everything but the channel are NOT a
    /// match: channels are separate connections.
    #[test]
    fn detects_channel_mismatch() {
        let mut p = Program::new(2, Collective::AllGather, "bad");
        p.push(0, Op::Send { peer: 1, chunks: vec![0], step: 0, channel: 1 });
        p.push(1, Op::recv(0, vec![0], false, 0)); // channel 0
        let err = verify_program(&p).unwrap_err();
        assert!(err.to_string().contains("connection"), "{err}");
    }

    /// A hand-built two-channel all-gather verifies, with the striped
    /// chunk space (chunk `k·n + r` owned by rank `r`).
    #[test]
    fn two_channel_ag_ok() {
        let n = 2;
        let mut p = Program::new(n, Collective::AllGather, "2ch");
        for k in 0..2usize {
            for r in 0..n {
                let peer = 1 - r;
                p.push(r, Op::Send { peer, chunks: vec![k * n + r], step: 0, channel: k });
                p.push(
                    r,
                    Op::Recv {
                        peer,
                        chunks: vec![k * n + peer],
                        reduce: false,
                        step: 0,
                        channel: k,
                    },
                );
            }
        }
        verify_program(&p).unwrap();
    }

    #[test]
    fn detects_double_contribution() {
        let mut p = Program::new(2, Collective::ReduceScatter, "bad");
        push_pair(&mut p, 0, 1, vec![1], 0);
        push_pair(&mut p, 0, 1, vec![1], 1);
        push_pair(&mut p, 1, 0, vec![0], 0);
        let err = verify_program(&p).unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
    }

    #[test]
    fn minimal_ag_2ranks_ok() {
        let mut p = Program::new(2, Collective::AllGather, "ok");
        push_pair(&mut p, 0, 1, vec![0], 0);
        push_pair(&mut p, 1, 0, vec![1], 0);
        let occ = verify_program(&p).unwrap();
        assert_eq!(occ.peak_slots, 0); // nothing is ever forwarded
    }

    #[test]
    fn minimal_rs_2ranks_ok() {
        let mut p = Program::new(2, Collective::ReduceScatter, "ok");
        push_pair(&mut p, 0, 1, vec![1], 0);
        push_pair(&mut p, 1, 0, vec![0], 0);
        verify_program(&p).unwrap();
    }

    /// Staging occupancy: a 3-rank relay where rank 1 must hold rank 0's
    /// chunk before forwarding it to rank 2.
    #[test]
    fn staging_occupancy_counted() {
        let mut p = Program::new(3, Collective::AllGather, "relay");
        push_pair(&mut p, 0, 1, vec![0], 0);
        push_pair(&mut p, 1, 2, vec![0], 1); // forward: chunk 0 staged at rank 1
        push_pair(&mut p, 1, 2, vec![1], 1);
        push_pair(&mut p, 1, 0, vec![1], 1);
        push_pair(&mut p, 2, 0, vec![2], 2);
        push_pair(&mut p, 2, 1, vec![2], 2);
        let occ = verify_program(&p).unwrap();
        assert_eq!(occ.peak_slots, 1);
        assert_eq!(occ.peak_rank, 1);
    }
}
