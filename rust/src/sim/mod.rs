//! Event-driven network simulator used for at-scale evaluation.
//!
//! The paper's motivation is fabric behaviour NCCL observes at thousands of
//! ranks: static routing collisions and tapered upper tiers make the "send
//! half the data to the most distant rank" steps of Bruck / recursive
//! doubling run far slower than the α-β model predicts. This simulator
//! reproduces exactly that mechanism:
//!
//! * [`topology`] — flat crossbar, 2-/3-level fat-trees (with taper), and a
//!   dragonfly-lite, all exposing per-message link paths;
//! * [`routing`] — deterministic (static) ECMP path selection by flow hash,
//!   so distinct flows can collide on an uplink, as on real IB fabrics;
//! * [`cost`] — the α-β-γ cost model: per-message software overhead α_base,
//!   per-hop latency α_hop, per-byte link serialization β, per-chunk local
//!   pack/unpack cost γ (PAT's "linear part is local"), NIC message-rate
//!   limits (Ring's linear part), and reduction cost on the RS datapath;
//! * [`engine`] — executes a [`crate::sched::Program`] against a topology +
//!   cost model, tracking per-link busy intervals (contention) and per-rank
//!   serialization, producing completion time and traffic metrics;
//! * [`fault`] — deterministic fault axes (seeded per-message jitter,
//!   link-flap windows) and [`fault::robustness`], the clean-vs-faulted
//!   slowdown the adversary harness ([`crate::adversary`]) records for
//!   the simulator side (`patcol simulate --jitter/--flaps`).
//!
//! [`engine::simulate_observed`] additionally emits the unified
//! [`crate::obs`] event timeline (op spans, wire transit, stalls,
//! reductions) from the discrete-event loop — the same schema the threaded
//! transport records, so simulated and measured timelines load side by
//! side in the same trace viewer.

pub mod topology;
pub mod routing;
pub mod cost;
pub mod engine;
pub mod fault;

pub use cost::CostModel;
pub use engine::{
    simulate, simulate_faulted, simulate_observed, simulate_sized, simulate_traced, SimReport,
    TraceEvent,
};
pub use fault::{robustness, FaultModel, LinkFlap, Robustness};
pub use topology::Topology;
