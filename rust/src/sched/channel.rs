//! First-class channels: split any collective program across NCCL-style
//! channels, and the FIFO-safe stream-merge machinery shared with the
//! composer.
//!
//! At bandwidth-bound sizes NCCL runs a single all-gather or reduce-scatter
//! over multiple *channels* — parallel connections with their own proxy
//! streams and (on ECMP fabrics) their own statically-hashed paths — so one
//! collective can use parallel links instead of serializing behind a single
//! flow. Here the channel is an IR concept ([`Op::channel`]): the splitter
//! below takes *any* generated program (pat / ring / bruck / tree / hier,
//! either collective, even an already-composed all-reduce) and shards it
//! across `C` channels by **chunk striping**:
//!
//! * the payload splits into `C` equal stripes; stripe `k` becomes an
//!   independent copy of the base schedule over its own chunk ids
//!   `k·chunk_space + c` (ownership is preserved: chunk ids are owned by
//!   `id mod nranks`, and `chunk_space` is a multiple of `nranks`);
//! * copy `k`'s ops run on channels `k·base_channels + old_channel`, so
//!   splitting composes with programs that already carry channels
//!   (splitting a 2-segment all-reduce across 2 stripes yields 4 channels);
//! * each rank's op list is the [`merge_rank_streams`] merge of its `C`
//!   per-copy streams, keyed by `(step, stripe)` — the same FIFO-safety
//!   argument as the composer's (see below), so the merged list is a valid
//!   linear extension that the single-stream reference executor can run.
//!
//! The composer ([`crate::sched::compose`]) is a *user* of the same
//! machinery: its pipeline segments are channels (segment `s`'s phase
//! streams merge with `channel_base = s`), rather than a chunk-id
//! convention for downstream layers to re-infer. The bucket fuser
//! ([`crate::sched::bucket`]) is the second user, merging whole
//! *operations*: every (bucket, segment) is a channel, so one
//! [`merge_rank_streams`] call per rank interleaves an entire
//! gradient-bucket batch under the same FIFO argument (and
//! [`crate::sched::bucket::fuse_striped`] applies the splitter's chunk
//! striping selectively, per bucket). The hierarchical scheduler
//! ([`crate::sched::hier`]) is the third user: each of a node's `L`
//! stripe leaders owns the local chunks congruent to its stripe index mod
//! `L`, and the per-leader phase streams merge with `channel_base =
//! stripe index` — `L` inter-node flows per node with distinct ECMP salts
//! instead of one leader's single flow.
//!
//! ## Why the merge preserves FIFO
//!
//! Every stream is merged by the key `(step_base + op.step, stream index)`
//! with in-stream order preserved. A message's send and recv carry the same
//! source step, and live at the same stream index on their two ranks
//! (stripe `k` everywhere, or (segment, phase) everywhere for the
//! composer). Both endpoints therefore order any two messages of a
//! connection identically, so the k-th send `s → d` on a channel still
//! faces the k-th recv at `d` from `s` on that channel: per-(src, dst,
//! channel) FIFO survives both splitting and composition.

use crate::core::{ChunkId, Error, Rank, Result};
use crate::sched::program::{Op, Program};

/// One source op stream feeding [`merge_rank_streams`]: a slice of ops plus
/// the offsets that re-home it onto the output program's step grid, chunk
/// space and channel range.
pub struct Stream<'a> {
    pub ops: &'a [Op],
    /// Added to every op's step.
    pub step_base: usize,
    /// Added to every chunk id.
    pub chunk_base: usize,
    /// Added to every op's channel.
    pub channel_base: usize,
}

/// Merge `streams` into `out.ranks[rank]`, ordered by `(step_base +
/// op.step, stream index)` with in-stream order preserved, remapping
/// chunks, steps and channels by each stream's bases. Callers must build
/// the stream list in the same order on every rank — the stream index is
/// the tie-break that keeps both endpoints of a connection in agreement
/// (see the module docs for the FIFO argument).
pub fn merge_rank_streams(out: &mut Program, rank: Rank, streams: &[Stream<'_>]) {
    let mut idx = vec![0usize; streams.len()];
    loop {
        let mut best: Option<(usize, (usize, usize))> = None;
        for (i, st) in streams.iter().enumerate() {
            if let Some(op) = st.ops.get(idx[i]) {
                let key = (st.step_base + op.step(), i);
                if best.map(|(_, bk)| key < bk).unwrap_or(true) {
                    best = Some((i, key));
                }
            }
        }
        let Some((i, _)) = best else { break };
        let st = &streams[i];
        let op = &st.ops[idx[i]];
        idx[i] += 1;
        let remap = |chunks: &[ChunkId]| -> Vec<ChunkId> {
            chunks.iter().map(|&c| st.chunk_base + c).collect()
        };
        let merged = match op {
            Op::Send { peer, chunks, step, channel } => Op::Send {
                peer: *peer,
                chunks: remap(chunks),
                step: st.step_base + step,
                channel: st.channel_base + channel,
            },
            Op::Recv { peer, chunks, reduce, step, channel } => Op::Recv {
                peer: *peer,
                chunks: remap(chunks),
                reduce: *reduce,
                step: st.step_base + step,
                channel: st.channel_base + channel,
            },
        };
        out.push(rank, merged);
    }
}

/// Split `p` across `channels` stripes (see the module docs). `channels ==
/// 1` returns the program unchanged; the split program's algorithm name is
/// `{base}*{channels}` (the CLI/config channel spelling), its chunk space
/// `channels × chunk_space(p)`, and its channel count `channels ×
/// p.channels`.
pub fn split(p: &Program, channels: usize) -> Result<Program> {
    if channels == 0 {
        return Err(Error::Schedule("channel split requires channels >= 1".into()));
    }
    if channels == 1 {
        return Ok(p.clone());
    }
    let base_chunks = p.chunk_space();
    let base_channels = p.channels;
    let mut out = Program::new(
        p.nranks,
        p.collective,
        format!("{}*{channels}", p.algorithm),
    );
    for rank in 0..p.nranks {
        let streams: Vec<Stream<'_>> = (0..channels)
            .map(|k| Stream {
                ops: &p.ranks[rank],
                step_base: 0,
                chunk_base: k * base_chunks,
                channel_base: k * base_channels,
            })
            .collect();
        merge_rank_streams(&mut out, rank, &streams);
    }
    debug_assert_eq!(out.collective, p.collective);
    Ok(out)
}

/// The per-(rank, channel) op streams of a program — the unit the
/// simulator and the threaded transport execute, and what tests compare
/// when asserting two constructions are channel-for-channel identical.
pub fn per_channel_streams(p: &Program) -> Vec<Vec<Vec<&Op>>> {
    let nchan = p.channels.max(1);
    let mut out: Vec<Vec<Vec<&Op>>> = vec![vec![Vec::new(); nchan]; p.nranks];
    for (r, ops) in p.ranks.iter().enumerate() {
        for op in ops {
            out[r][op.channel()].push(op);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::verify::verify_program;
    use crate::sched::{bruck, hier, pat, ring};

    #[test]
    fn rejects_zero_channels_and_identity_at_one() {
        let p = pat::allgather(8, 2);
        assert!(split(&p, 0).is_err());
        let same = split(&p, 1).unwrap();
        assert_eq!(same, p);
    }

    #[test]
    fn split_structure() {
        let p = ring::allgather(6);
        let s = split(&p, 4).unwrap();
        assert_eq!(s.nranks, 6);
        assert_eq!(s.channels, 4);
        assert_eq!(s.chunk_space(), 4 * 6);
        assert_eq!(s.total_ops(), 4 * p.total_ops());
        assert_eq!(s.steps, p.steps);
        assert_eq!(s.algorithm, "ring*4");
        // chunk transfers multiply by the channel count (each stripe moves
        // the full n(n-1) grid of its own, 1/C-sized, chunks)
        assert_eq!(s.stats().chunk_transfers, 4 * p.stats().chunk_transfers);
    }

    /// Every generator × both collectives × channel counts verifies after
    /// splitting — the splitter is generator-agnostic.
    #[test]
    fn split_verifies_across_generators() {
        let pl = crate::core::Placement::uniform(12, 4).unwrap();
        let programs = vec![
            ring::allgather(5),
            bruck::allgather_near_first(9),
            bruck::allgather_far_first(8),
            crate::sched::recursive::allgather(8),
            pat::allgather(12, 2),
            pat::allgather(7, usize::MAX),
            hier::allgather(&pl, 2),
        ];
        for p in programs {
            for c in [2usize, 3, 4, 8] {
                let s = split(&p, c).unwrap();
                verify_program(&s)
                    .unwrap_or_else(|e| panic!("{}*{c} ag: {e}", p.algorithm));
                let srs = split(&p.mirror(), c).unwrap();
                verify_program(&srs)
                    .unwrap_or_else(|e| panic!("{}*{c} rs: {e}", p.algorithm));
            }
        }
    }

    /// Splitting and mirroring commute channel-for-channel: the mirror of
    /// a split all-gather carries exactly the per-channel streams of the
    /// split of the mirror (the merged interleave differs — mirroring
    /// reverses the within-step channel order — but each channel's stream,
    /// which is what the executors drive, is identical including steps).
    #[test]
    fn split_commutes_with_mirror() {
        let p = pat::allgather(9, 2);
        let a = split(&p, 4).unwrap().mirror();
        let b = split(&p.mirror(), 4).unwrap();
        assert_eq!(a.collective, b.collective);
        assert_eq!(a.channels, b.channels);
        let sa = per_channel_streams(&a);
        let sb = per_channel_streams(&b);
        for r in 0..p.nranks {
            for k in 0..a.channels {
                assert_eq!(sa[r][k], sb[r][k], "rank {r} channel {k}");
            }
        }
    }

    /// Splitting an already-composed (multi-channel) all-reduce program
    /// multiplies the channel count and still verifies.
    #[test]
    fn split_composed_allreduce() {
        let rs = pat::reduce_scatter(8, 2);
        let ag = pat::allgather(8, 2);
        let fused = crate::sched::compose::fuse(&rs, &ag, 2).unwrap();
        assert_eq!(fused.channels, 2);
        let s = split(&fused, 2).unwrap();
        assert_eq!(s.channels, 4);
        assert_eq!(s.chunk_space(), 2 * fused.chunk_space());
        verify_program(&s).unwrap();
    }

    /// The regression test for the simulator's old compose-only channel
    /// inference: a composed `S`-segment all-reduce and the channel-split
    /// of the equivalent sequential composition carry identical
    /// per-(rank, channel) op streams — same kinds, peers, chunks and
    /// reduce flags, in the same per-channel order (only the step
    /// numbering differs: compose staggers segments, split does not). The
    /// executors drive per-channel streams, so the two programs execute
    /// identically.
    #[test]
    fn compose_segments_equal_channel_split_streams() {
        let n = 12;
        let segments = 3;
        let rs = pat::reduce_scatter(n, 2);
        let ag = ring::allgather(n);
        let composed = crate::sched::compose::fuse(&rs, &ag, segments).unwrap();
        let sequential = crate::sched::compose::fuse(&rs, &ag, 1).unwrap();
        let split_seq = split(&sequential, segments).unwrap();
        assert_eq!(composed.channels, segments);
        assert_eq!(split_seq.channels, segments);
        let key = |op: &Op| {
            (
                op.is_send(),
                op.peer(),
                op.chunks().to_vec(),
                matches!(op, Op::Recv { reduce: true, .. }),
            )
        };
        let a = per_channel_streams(&composed);
        let b = per_channel_streams(&split_seq);
        for r in 0..n {
            for k in 0..segments {
                let sa: Vec<_> = a[r][k].iter().map(|op| key(op)).collect();
                let sb: Vec<_> = b[r][k].iter().map(|op| key(op)).collect();
                assert_eq!(sa, sb, "rank {r} channel {k}");
            }
        }
    }

    /// Chunk ownership is preserved by the stripe renaming: every chunk id
    /// a rank sends without having received belongs to it (`id % n == r`).
    #[test]
    fn ownership_preserved() {
        let s = split(&pat::allgather(10, usize::MAX), 3).unwrap();
        let n = s.nranks;
        for (r, ops) in s.ranks.iter().enumerate() {
            let mut held: std::collections::HashSet<usize> =
                (0..s.chunk_space()).filter(|c| c % n == r).collect();
            for op in ops {
                match op {
                    Op::Recv { chunks, .. } => held.extend(chunks.iter().copied()),
                    Op::Send { chunks, .. } => {
                        for c in chunks {
                            assert!(held.contains(c), "rank {r} sends unheld chunk {c}");
                        }
                    }
                }
            }
        }
    }
}
