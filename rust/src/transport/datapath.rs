//! The receive-side reduction datapath.
//!
//! Reduce-scatter folds every incoming chunk into an accumulator — the
//! compute hot-spot the paper's NCCL implementation runs as a GPU kernel.
//! Two implementations:
//!
//! * [`DataPath::Scalar`] — a plain rust loop (auto-vectorized); the
//!   baseline and fallback.
//! * [`DataPath::Pjrt`] — the AOT-compiled Pallas reduce kernel executed
//!   through the PJRT service thread ([`crate::runtime::PjrtHandle`]; the
//!   `xla` crate's handles are not `Send`, so one thread owns the client —
//!   the analog of kernels serializing on a device stream). Three-layer
//!   path: Pallas (L1) → jax graph (L2) → rust runtime (L3).

use crate::core::{Rank, Result};
use crate::obs::{Event, EventKind, FlightRecorder};
use crate::runtime::PjrtHandle;

/// Reduction backend used by the transport engine.
#[derive(Clone)]
pub enum DataPath {
    /// Pure-rust elementwise add.
    Scalar,
    /// AOT Pallas kernel via the PJRT service thread.
    Pjrt(PjrtHandle),
}

impl DataPath {
    /// `acc[i] += x[i]` for all i.
    pub fn reduce_into(&self, acc: &mut [f32], x: &[f32]) -> Result<()> {
        debug_assert_eq!(acc.len(), x.len());
        match self {
            DataPath::Scalar => {
                scalar_add(acc, x);
                Ok(())
            }
            DataPath::Pjrt(h) => h.reduce_into(acc, x),
        }
    }

    /// Append `a + b` to `out` (3-operand fused form for the send path:
    /// one read of each operand, one write of the destination — versus the
    /// reduce-into-slot-then-copy sequence's extra round trip; perf pass,
    /// EXPERIMENTS.md §Perf).
    pub fn add_extend(&self, out: &mut Vec<f32>, a: &[f32], b: &[f32]) -> Result<()> {
        debug_assert_eq!(a.len(), b.len());
        match self {
            DataPath::Scalar => {
                out.extend(a.iter().zip(b.iter()).map(|(x, y)| x + y));
                Ok(())
            }
            DataPath::Pjrt(h) => {
                let base = out.len();
                out.extend_from_slice(a);
                h.reduce_into(&mut out[base..], b)
            }
        }
    }

    /// [`DataPath::reduce_into`] wrapped in a reduce-kernel span when the
    /// flight recorder is enabled (single branch + no clock reads when
    /// disabled — the hot path stays untouched).
    #[allow(clippy::too_many_arguments)]
    pub fn reduce_into_traced(
        &self,
        acc: &mut [f32],
        x: &[f32],
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) -> Result<()> {
        if !fr.enabled() {
            return self.reduce_into(acc, x);
        }
        let t0 = fr.now();
        self.reduce_into(acc, x)?;
        let t1 = fr.now();
        fr.record(
            Event::span(EventKind::Reduce, rank, channel, step, t0, t1)
                .with_bytes(std::mem::size_of_val(x)),
        );
        Ok(())
    }

    /// [`DataPath::add_extend`] wrapped in a reduce-kernel span (see
    /// [`DataPath::reduce_into_traced`]).
    #[allow(clippy::too_many_arguments)]
    pub fn add_extend_traced(
        &self,
        out: &mut Vec<f32>,
        a: &[f32],
        b: &[f32],
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) -> Result<()> {
        if !fr.enabled() {
            return self.add_extend(out, a, b);
        }
        let t0 = fr.now();
        self.add_extend(out, a, b)?;
        let t1 = fr.now();
        fr.record(
            Event::span(EventKind::Reduce, rank, channel, step, t0, t1)
                .with_bytes(std::mem::size_of_val(b)),
        );
        Ok(())
    }

    pub fn name(&self) -> &'static str {
        match self {
            DataPath::Scalar => "scalar",
            DataPath::Pjrt(_) => "pjrt",
        }
    }
}

/// The scalar kernel, split out so benches can target it directly.
#[inline]
pub fn scalar_add(acc: &mut [f32], x: &[f32]) {
    for (a, b) in acc.iter_mut().zip(x.iter()) {
        *a += *b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_adds() {
        let mut acc = vec![1.0, 2.0, 3.0];
        DataPath::Scalar.reduce_into(&mut acc, &[10.0, 20.0, 30.0]).unwrap();
        assert_eq!(acc, vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn names() {
        assert_eq!(DataPath::Scalar.name(), "scalar");
    }
}
