//! Ring all-gather — NCCL's historical algorithm for AG/RS and the paper's
//! primary baseline: `n-1` steps, each moving one chunk to the next rank.
//! Bandwidth-optimal, but latency is linear in the number of ranks.

use crate::core::Collective;
use crate::sched::program::{Op, Program};

/// Ring all-gather. At step `s`, rank `i` sends chunk `(i - s) mod n` to
/// `i+1` and receives chunk `(i - 1 - s) mod n` from `i-1`; after `n-1`
/// steps every chunk has visited every rank.
pub fn allgather(n: usize) -> Program {
    let mut p = Program::new(n, Collective::AllGather, "ring");
    if n <= 1 {
        return p;
    }
    for s in 0..n - 1 {
        for i in 0..n {
            let next = (i + 1) % n;
            let prev = (i + n - 1) % n;
            let send_chunk = (i + n - s % n) % n;
            let recv_chunk = (prev + n - s % n) % n;
            p.push(i, Op::send(next, vec![send_chunk], s));
            p.push(i, Op::recv(prev, vec![recv_chunk], false, s));
        }
    }
    p
}

/// Ring reduce-scatter: the mirror of ring all-gather. Chunk `c` starts at
/// rank `c+1`, travels the ring accumulating each rank's contribution, and
/// lands fully-reduced on rank `c`.
pub fn reduce_scatter(n: usize) -> Program {
    allgather(n).mirror()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::verify::verify_program;

    #[test]
    fn ring_ag_structure() {
        let p = allgather(4);
        assert_eq!(p.steps, 3);
        let s = p.stats();
        assert_eq!(s.messages, 12); // (n-1) * n
        assert_eq!(s.max_aggregation, 1);
    }

    #[test]
    fn ring_ag_correct_small() {
        for n in 1..12 {
            verify_program(&allgather(n)).unwrap();
        }
    }

    #[test]
    fn ring_rs_correct_small() {
        for n in 1..12 {
            verify_program(&reduce_scatter(n)).unwrap();
        }
    }

    #[test]
    fn ring_rs_linear_steps() {
        let p = reduce_scatter(8);
        assert_eq!(p.steps, 7);
    }
}
