//! PJRT datapath service.
//!
//! The `xla` crate's PJRT handles are `Rc`-based (not `Send`), so a single
//! dedicated service thread owns the [`Registry`] and executes reduction
//! requests on behalf of all rank threads — the moral equivalent of kernels
//! serializing onto one accelerator stream. Rank threads hold a cloneable
//! [`PjrtHandle`] and block on a reply channel per call.
//!
//! The perf pass can shard requests over several service threads (one
//! client each) if the single stream becomes the bottleneck; see
//! EXPERIMENTS.md §Perf.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use crate::core::{Error, Result};
use crate::runtime::artifacts::Registry;
use crate::runtime::client::PjrtContext;

enum Request {
    /// acc += x elementwise; replies with the updated acc.
    Reduce {
        acc: Vec<f32>,
        x: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the PJRT service thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Sender<Request>,
}

impl PjrtHandle {
    /// `acc += x` through the AOT Pallas reduce kernel.
    pub fn reduce_into(&self, acc: &mut [f32], x: &[f32]) -> Result<()> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request::Reduce {
                acc: acc.to_vec(),
                x: x.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| Error::Runtime("pjrt service is down".into()))?;
        let out = reply_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt service dropped reply".into()))??;
        acc.copy_from_slice(&out);
        Ok(())
    }
}

/// Owns the service thread; dropping shuts it down.
pub struct PjrtService {
    tx: Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl PjrtService {
    /// Spawn the service over the artifact directory (must contain
    /// `manifest.json`; see `make artifacts`). Fails fast if the registry
    /// cannot be loaded.
    pub fn spawn(artifact_dir: PathBuf) -> Result<(PjrtService, PjrtHandle)> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let reg = match PjrtContext::cpu()
                    .and_then(|ctx| Registry::load(ctx, &artifact_dir))
                {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Reduce { mut acc, x, reply } => {
                            let res = reg.reduce_f32(&mut acc, &x).map(|()| acc);
                            let _ = reply.send(res);
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn pjrt service: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt service died during startup".into()))??;
        let handle = PjrtHandle { tx: tx.clone() };
        Ok((PjrtService { tx, join: Some(join) }, handle))
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Startup failure surfaces as a clean error: either the registry
    /// pointer ("make artifacts") with a real backend, or the stub's
    /// backend-unavailable message.
    #[test]
    fn startup_failure_is_reported() {
        let err = PjrtService::spawn(PathBuf::from("/nonexistent")).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("make artifacts") || msg.contains("unavailable"),
            "{msg}"
        );
    }
}

impl std::fmt::Debug for PjrtHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("PjrtHandle")
    }
}

impl std::fmt::Debug for PjrtService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("PjrtService")
    }
}
