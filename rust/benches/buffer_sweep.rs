//! F7–F9 / P3 — the aggregation (parallel-tree) sweep and the buffer law.
//!
//! Regenerates the Figs. 7–9 transition (8 → 4 → 2 trees on 16 ranks) as
//! step counts + simulated times, and measures the reduce-scatter
//! accumulator high-water mark across rank counts and *operation sizes*:
//! the paper's claim is that buffer need is logarithmic in ranks and
//! independent of total size (law: a · log2(n/a) chunk slots).

use patcol::core::{ceil_log2, floor_log2};
use patcol::report::Report;
use patcol::sched::pat;
use patcol::sched::verify::verify_program;
use patcol::sim::{simulate, CostModel, Topology};
use patcol::transport::{run_reduce_scatter, TransportOptions};
use patcol::util::json::Json;
use patcol::util::table::{fmt_time_s, Table};
use patcol::util::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = Report::new("buffer_sweep");

    // --- Figs. 7-9: 16 ranks, trees 8/4/2/1 -------------------------------
    let n = 16;
    let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
    let cost = CostModel::ib_hdr();
    println!("\nFigs. 7-9: PAT on {n} ranks across aggregation factors");
    let mut t = Table::new(["trees", "steps", "log", "lin", "t(1KiB)", "t(256KiB)"]);
    for a in [8usize, 4, 2, 1] {
        let ag = pat::allgather(n, a);
        let (lg, ln) = pat::phase_counts(n, a);
        let t1 = simulate(&ag, &topo, &cost, 1 << 10).unwrap().total_time;
        let t2 = simulate(&ag, &topo, &cost, 256 << 10).unwrap().total_time;
        t.row([
            format!("{a}"),
            format!("{}", ag.steps),
            format!("{lg}"),
            format!("{ln}"),
            fmt_time_s(t1),
            fmt_time_s(t2),
        ]);
        report.rows.push(Json::obj(vec![
            ("kind", Json::str("fig7_9")),
            ("trees", Json::num(a as f64)),
            ("steps", Json::num(ag.steps as f64)),
            ("log_steps", Json::num(lg as f64)),
            ("lin_steps", Json::num(ln as f64)),
            ("t_small", Json::num(t1)),
            ("t_large", Json::num(t2)),
        ]));
    }
    print!("{}", t.render());
    println!("(expected steps 4/5/8/15 — Figs. 7, 8, 9, 10)");

    // --- P3a: accumulator occupancy vs rank count (structural) ------------
    println!("\nreduce-scatter accumulator slots vs ranks (law: a*log2(n/a)):");
    let mut t = Table::new(["ranks", "a=1", "a=2", "a=4", "a=8"]);
    let kmax = if smoke { 5usize } else { 10 };
    for k in 3..=kmax {
        let n = 1usize << k;
        let mut row = vec![format!("{n}")];
        for a in [1usize, 2, 4, 8] {
            let occ = verify_program(&pat::reduce_scatter(n, a)).unwrap();
            let a_eff = pat::clamp_aggregation(n, a);
            let law = a_eff * (ceil_log2(n) as usize).saturating_sub(floor_log2(a_eff) as usize).max(1);
            assert!(occ.peak_slots <= law, "n={n} a={a}: {} > {law}", occ.peak_slots);
            row.push(format!("{}", occ.peak_slots));
            report.rows.push(Json::obj(vec![
                ("kind", Json::str("occupancy_vs_ranks")),
                ("ranks", Json::num(n as f64)),
                ("a", Json::num(a as f64)),
                ("peak_slots", Json::num(occ.peak_slots as f64)),
                ("law", Json::num(law as f64)),
            ]));
        }
        t.row(row);
    }
    print!("{}", t.render());

    // --- P3b: occupancy is independent of operation size (real bytes) -----
    println!("\nreduce-scatter accumulator slots vs chunk size (16 ranks, a=2, real transport):");
    let mut t = Table::new(["chunk elems", "peak slots"]);
    let prog = pat::reduce_scatter(16, 2);
    let mut rng = Rng::new(5);
    let chunks: &[usize] = if smoke { &[16, 256] } else { &[16, 256, 4096, 65536] };
    for &chunk in chunks {
        let inputs: Vec<Vec<f32>> = (0..16)
            .map(|_| (0..16 * chunk).map(|_| rng.below(100) as f32).collect())
            .collect();
        let (_, rep) = run_reduce_scatter(&prog, &inputs, &TransportOptions::default()).unwrap();
        t.row([format!("{chunk}"), format!("{}", rep.peak_slots)]);
        report.rows.push(Json::obj(vec![
            ("kind", Json::str("occupancy_vs_size")),
            ("chunk_elems", Json::num(chunk as f64)),
            ("peak_slots", Json::num(rep.peak_slots as f64)),
        ]));
    }
    print!("{}", t.render());
    println!("(constant across sizes — the paper's 'independently from the total operation size')");

    report.save().unwrap();
}
