//! Quickstart: create a communicator, all-gather and reduce-scatter over
//! real bytes, inspect what the library did.
//!
//!     cargo run --release --example quickstart

use patcol::coordinator::{CommConfig, Communicator};
use patcol::core::Algorithm;
use patcol::util::table::{fmt_bytes, fmt_time_s};

fn main() -> patcol::core::Result<()> {
    let nranks = 8;
    let chunk = 4096; // f32 elements contributed per rank

    // A communicator with the PAT algorithm pinned at aggregation 2
    // (paper Figs. 5-6: one logarithmic step, then two parallel trees).
    let comm = Communicator::new(CommConfig {
        nranks,
        algorithm: Some(Algorithm::Pat { aggregation: 2 }),
        ..Default::default()
    })?;

    // --- all-gather ------------------------------------------------------
    let inputs: Vec<Vec<f32>> = (0..nranks).map(|r| vec![r as f32; chunk]).collect();
    let (gathered, rep) = comm.all_gather_report(&inputs)?;
    println!(
        "all-gather     {} steps={} msgs={} moved={} wall={}",
        rep.algorithm,
        rep.steps,
        rep.transport.messages,
        fmt_bytes(rep.transport.bytes_moved),
        fmt_time_s(rep.transport.wall.as_secs_f64()),
    );
    for (r, out) in gathered.iter().enumerate() {
        assert_eq!(out.len(), nranks * chunk);
        for src in 0..nranks {
            assert!(out[src * chunk..(src + 1) * chunk]
                .iter()
                .all(|&v| v == src as f32));
        }
        if r == 0 {
            println!("  rank 0 received chunks from all {nranks} ranks — verified");
        }
    }

    // --- reduce-scatter --------------------------------------------------
    // rank r contributes (r+1) to every element of every chunk; chunk c's
    // reduced value is therefore sum(1..=nranks) everywhere.
    let inputs: Vec<Vec<f32>> = (0..nranks)
        .map(|r| vec![(r + 1) as f32; nranks * chunk])
        .collect();
    let (reduced, rep) = comm.reduce_scatter_report(&inputs)?;
    let want = (nranks * (nranks + 1) / 2) as f32;
    for (r, out) in reduced.iter().enumerate() {
        assert_eq!(out.len(), chunk);
        assert!(out.iter().all(|&v| v == want), "rank {r}");
    }
    println!(
        "reduce-scatter {} steps={} msgs={} moved={} wall={} peak_acc_slots={}",
        rep.algorithm,
        rep.steps,
        rep.transport.messages,
        fmt_bytes(rep.transport.bytes_moved),
        fmt_time_s(rep.transport.wall.as_secs_f64()),
        rep.transport.peak_slots,
    );
    println!("  every rank holds its fully-reduced chunk (= {want}) — verified");

    // --- let the tuner decide -------------------------------------------
    let auto = Communicator::new(CommConfig { nranks, ..Default::default() })?;
    for bytes in [64usize, 1 << 20] {
        let alg = auto.resolve(patcol::core::Collective::AllGather, bytes);
        println!("tuner picks {alg} for {} per rank", fmt_bytes(bytes));
    }
    Ok(())
}
