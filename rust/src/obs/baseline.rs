//! Bench-baseline harness: stamp every bench run into one trajectory
//! document and check new runs against the committed baseline.
//!
//! Every [`crate::report::Report::save`] call checks the
//! [`BASELINE_ENV`] environment variable; when set, the report is also
//! merged into the baseline document it names (created on first use).
//! Running the bench suite with `PATCOL_BASELINE=BENCH_8.json` thus
//! produces a single schema-stamped JSON file with one entry per bench
//! — the repo's recorded bench trajectory, committed at the repo root
//! and compared against by the CI bench-baseline job.
//!
//! The document is deterministic (no timestamps, sorted keys) so that
//! re-running the suite on identical code yields a clean diff:
//!
//! ```text
//! { "schema_version": 3,
//!   "benches": { "latency_vs_size": { ...report... },
//!                "transport_hotpath": { ...report... } } }
//! ```
//!
//! [`check`] compares two such documents on machine-independent
//! metrics only — the reduce-path ABI speedup *ratio* from
//! `transport_hotpath`, the simulator-derived Träff optimality-gap
//! percentages from `latency_vs_size`, and the `hier_vs_flat`
//! hierarchy gates (leader-staging high-water ≤ the analytic
//! [`crate::sched::hier::staging_bound`] per leader count, hier Träff
//! gap non-growth) — never absolute wall times, which would tie the
//! committed baseline to one machine.

use std::path::Path;

use crate::core::Result;
use crate::obs::trace::SCHEMA_VERSION;
use crate::util::json::{self, Json};

/// Environment variable naming the baseline document to stamp bench
/// reports into.
pub const BASELINE_ENV: &str = "PATCOL_BASELINE";

/// Tolerated relative loss of the reduce-path speedup ratio vs the
/// committed baseline (the absolute ≥ 2× floor applies regardless).
const RATIO_SLACK: f64 = 0.75;
/// Tolerated relative growth of an optimality-gap percentage vs the
/// committed baseline, plus one percentage point of absolute slack.
const GAP_GROWTH: f64 = 1.10;
const GAP_SLACK_PCT: f64 = 1.0;

/// Load a baseline document (missing file → empty skeleton).
pub fn load(path: &Path) -> Result<Json> {
    match std::fs::read_to_string(path) {
        Ok(text) => json::parse(&text),
        Err(_) => Ok(empty()),
    }
}

fn empty() -> Json {
    Json::obj(vec![
        ("schema_version", Json::num(SCHEMA_VERSION as f64)),
        ("benches", Json::Obj(Default::default())),
    ])
}

/// Merge one bench report into the baseline document at `path`:
/// read-modify-write of `benches[name]`, preserving other entries.
pub fn stamp(path: &Path, name: &str, report: &Json) -> Result<()> {
    let mut doc = load(path)?;
    if doc.get("benches").and_then(|b| b.as_obj()).is_none() {
        doc = empty();
    }
    if let Json::Obj(top) = &mut doc {
        top.insert("schema_version".into(), Json::num(SCHEMA_VERSION as f64));
        if let Some(Json::Obj(benches)) = top.get_mut("benches") {
            benches.insert(name.to_string(), report.clone());
        }
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, doc.to_pretty())?;
    Ok(())
}

fn bench<'a>(doc: &'a Json, name: &str) -> Option<&'a Json> {
    doc.get("benches").and_then(|b| b.get(name))
}

/// The reduce-path ABI speedup ratio of a `transport_hotpath` report:
/// slice-descriptor GB/s at 2 shards over the owned-round-trip GB/s.
/// Machine-independent to first order — both sides run on the same
/// cores — which is why the baseline gates on the ratio, not on GB/s.
pub fn reduce_path_ratio(doc: &Json) -> Option<f64> {
    let rows = bench(doc, "transport_hotpath")?.get("rows")?.as_arr()?;
    let find = |abi: &str, shards: usize| {
        rows.iter().find_map(|r| {
            if r.get("kind")?.as_str()? != "reduce_path" {
                return None;
            }
            if r.get("abi")?.as_str()? != abi || r.get("shards")?.as_usize()? != shards {
                return None;
            }
            r.get("gbps")?.as_f64()
        })
    };
    let owned = find("owned", 1)?;
    let slice2 = find("slice", 2)?;
    if owned > 0.0 {
        Some(slice2 / owned)
    } else {
        None
    }
}

/// The Träff optimality-gap percentages of a `latency_vs_size` report
/// (deterministic: simulator-derived), as `(param, pct)` pairs.
pub fn optimality_gaps(doc: &Json) -> Vec<(String, f64)> {
    let Some(params) = bench(doc, "latency_vs_size")
        .and_then(|b| b.get("params"))
        .and_then(|p| p.as_obj())
    else {
        return Vec::new();
    };
    params
        .iter()
        .filter(|(k, _)| k.ends_with("_gap_pct"))
        .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
        .collect()
}

/// The `hier_vs_flat` leader-staging parameter pairs, as
/// `(leader label, high water, analytic bound)` — one per
/// `staging_hw_l<L>` / `staging_bound_l<L>` pair in the report. Both
/// sides are chunk-count-shaped (reference-executor occupancy vs the
/// [`crate::sched::hier::staging_bound`] law), so the gate is exact and
/// machine-independent.
pub fn staging_pairs(doc: &Json) -> Vec<(String, f64, f64)> {
    let Some(params) = bench(doc, "hier_vs_flat")
        .and_then(|b| b.get("params"))
        .and_then(|p| p.as_obj())
    else {
        return Vec::new();
    };
    params
        .iter()
        .filter_map(|(k, v)| {
            let l = k.strip_prefix("staging_hw_")?;
            let hw = v.as_f64()?;
            let bound = params.get(format!("staging_bound_{l}").as_str())?.as_f64()?;
            Some((l.to_string(), hw, bound))
        })
        .collect()
}

/// The `hier_vs_flat` Träff gap percentage (simulator-derived,
/// deterministic).
pub fn hier_gap_pct(doc: &Json) -> Option<f64> {
    bench(doc, "hier_vs_flat")?
        .get("params")?
        .get("hier_gap_pct")?
        .as_f64()
}

/// Compare `current` against the `committed` baseline. Returns one
/// message per regression; empty means the gate passes. Metrics absent
/// from the committed baseline are not gated (first runs pass), but
/// metrics the committed baseline has and `current` lacks are
/// regressions — a bench silently dropping out must fail loudly.
pub fn check(current: &Json, committed: &Json) -> Vec<String> {
    let mut fails = Vec::new();

    let cur_ratio = reduce_path_ratio(current);
    if let Some(r) = cur_ratio {
        if r < 2.0 {
            fails.push(format!(
                "transport_hotpath reduce-path floor: slice@2/owned ratio {r:.2} < 2.0"
            ));
        }
    }
    match (cur_ratio, reduce_path_ratio(committed)) {
        (Some(cur), Some(base)) => {
            if cur < base * RATIO_SLACK {
                fails.push(format!(
                    "transport_hotpath reduce-path ratio regressed: {cur:.2} < \
                     {RATIO_SLACK} x committed {base:.2}"
                ));
            }
        }
        (None, Some(_)) => {
            fails.push("transport_hotpath reduce-path rows missing from current run".into())
        }
        _ => {}
    }

    let cur_gaps = optimality_gaps(current);
    for (name, base) in optimality_gaps(committed) {
        match cur_gaps.iter().find(|(k, _)| *k == name) {
            Some(&(_, cur)) => {
                if cur > base * GAP_GROWTH + GAP_SLACK_PCT {
                    fails.push(format!(
                        "latency_vs_size {name} regressed: {cur:.2}% > \
                         {GAP_GROWTH} x committed {base:.2}% + {GAP_SLACK_PCT}%"
                    ));
                }
            }
            None => fails.push(format!("latency_vs_size {name} missing from current run")),
        }
    }

    // Leader-staging law: an absolute gate on the current document (the
    // bench asserts it too, but the stamped numbers are what CI trusts —
    // this also catches hand-edited baselines).
    let cur_staging = staging_pairs(current);
    for (l, hw, bound) in &cur_staging {
        if hw > bound {
            fails.push(format!(
                "hier_vs_flat leader staging {l}: high water {hw:.0} > \
                 analytic bound {bound:.0}"
            ));
        }
    }
    if cur_staging.is_empty() && !staging_pairs(committed).is_empty() {
        fails.push("hier_vs_flat staging parameters missing from current run".into());
    }

    // Hier Träff gap: non-growth under the same rule as the
    // latency_vs_size gaps.
    match (hier_gap_pct(current), hier_gap_pct(committed)) {
        (Some(cur), Some(base)) => {
            if cur > base * GAP_GROWTH + GAP_SLACK_PCT {
                fails.push(format!(
                    "hier_vs_flat hier_gap_pct regressed: {cur:.2}% > \
                     {GAP_GROWTH} x committed {base:.2}% + {GAP_SLACK_PCT}%"
                ));
            }
        }
        (None, Some(_)) => {
            fails.push("hier_vs_flat hier_gap_pct missing from current run".into())
        }
        _ => {}
    }

    fails
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("patcol_baseline_{}_{name}", std::process::id()))
    }

    fn hotpath_report(owned: f64, slice2: f64) -> Json {
        Json::obj(vec![
            ("name", Json::str("transport_hotpath")),
            (
                "rows",
                Json::arr(vec![
                    Json::obj(vec![
                        ("kind", Json::str("reduce_path")),
                        ("abi", Json::str("owned")),
                        ("shards", Json::num(1.0)),
                        ("gbps", Json::num(owned)),
                    ]),
                    Json::obj(vec![
                        ("kind", Json::str("reduce_path")),
                        ("abi", Json::str("slice")),
                        ("shards", Json::num(2.0)),
                        ("gbps", Json::num(slice2)),
                    ]),
                ]),
            ),
        ])
    }

    fn latency_report(small_gap: f64, large_gap: f64) -> Json {
        Json::obj(vec![
            ("name", Json::str("latency_vs_size")),
            (
                "params",
                Json::obj(vec![
                    ("pat_small_gap_pct", Json::num(small_gap)),
                    ("pat_large_gap_pct", Json::num(large_gap)),
                ]),
            ),
        ])
    }

    fn doc(hot: Option<Json>, lat: Option<Json>) -> Json {
        let mut benches = Vec::new();
        if let Some(h) = hot {
            benches.push(("transport_hotpath", h));
        }
        if let Some(l) = lat {
            benches.push(("latency_vs_size", l));
        }
        Json::obj(vec![
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("benches", Json::obj(benches)),
        ])
    }

    #[test]
    fn stamp_builds_and_updates_the_document() {
        let path = tmp("stamp.json");
        let _ = std::fs::remove_file(&path);
        stamp(&path, "transport_hotpath", &hotpath_report(1.0, 3.0)).unwrap();
        stamp(&path, "latency_vs_size", &latency_report(10.0, 5.0)).unwrap();
        // re-stamp overwrites in place, preserving the other entry
        stamp(&path, "transport_hotpath", &hotpath_report(1.0, 4.0)).unwrap();
        let doc = load(&path).unwrap();
        assert_eq!(
            doc.get("schema_version").unwrap().as_usize(),
            Some(SCHEMA_VERSION as usize)
        );
        assert_eq!(doc.get("benches").unwrap().as_obj().unwrap().len(), 2);
        assert!((reduce_path_ratio(&doc).unwrap() - 4.0).abs() < 1e-12);
        assert_eq!(optimality_gaps(&doc).len(), 2);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn check_passes_identical_documents() {
        let d = doc(Some(hotpath_report(1.0, 3.0)), Some(latency_report(10.0, 5.0)));
        assert!(check(&d, &d).is_empty());
    }

    #[test]
    fn check_flags_floor_and_regressions() {
        let base = doc(Some(hotpath_report(1.0, 4.0)), Some(latency_report(10.0, 5.0)));
        // ratio fell below the absolute 2.0 floor AND below 0.75x baseline
        let bad_ratio = doc(Some(hotpath_report(1.0, 1.5)), Some(latency_report(10.0, 5.0)));
        let fails = check(&bad_ratio, &base);
        assert_eq!(fails.len(), 2, "{fails:?}");
        // gap grew past 1.1x + 1pt
        let bad_gap = doc(Some(hotpath_report(1.0, 4.0)), Some(latency_report(13.0, 5.0)));
        let fails = check(&bad_gap, &base);
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("pat_small_gap_pct"));
        // within tolerance: 10% -> 11.5% passes (1.1x + 1pt = 12)
        let ok = doc(Some(hotpath_report(1.0, 3.5)), Some(latency_report(11.5, 5.4)));
        assert!(check(&ok, &base).is_empty());
    }

    fn hier_report(hw2: f64, bound2: f64, gap: f64) -> Json {
        Json::obj(vec![
            ("name", Json::str("hier_vs_flat")),
            (
                "params",
                Json::obj(vec![
                    ("staging_hw_l2", Json::num(hw2)),
                    ("staging_bound_l2", Json::num(bound2)),
                    ("hier_gap_pct", Json::num(gap)),
                ]),
            ),
        ])
    }

    fn doc_with_hier(hier: Option<Json>) -> Json {
        let mut benches = Vec::new();
        if let Some(h) = hier {
            benches.push(("hier_vs_flat", h));
        }
        Json::obj(vec![
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("benches", Json::obj(benches)),
        ])
    }

    #[test]
    fn hier_gates_extract_and_check() {
        let good = doc_with_hier(Some(hier_report(40.0, 58.0, 25.0)));
        assert_eq!(staging_pairs(&good), vec![("l2".to_string(), 40.0, 58.0)]);
        assert_eq!(hier_gap_pct(&good), Some(25.0));
        assert!(check(&good, &good).is_empty());

        // staging over the analytic bound fails absolutely (even against
        // an empty committed baseline)
        let over = doc_with_hier(Some(hier_report(60.0, 58.0, 25.0)));
        let fails = check(&over, &empty());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("leader staging l2"));

        // gap growth past 1.1x + 1pt fails; within passes
        let grown = doc_with_hier(Some(hier_report(40.0, 58.0, 30.0)));
        let fails = check(&grown, &good);
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("hier_gap_pct"));
        let ok = doc_with_hier(Some(hier_report(40.0, 58.0, 28.0)));
        assert!(check(&ok, &good).is_empty());

        // hier bench dropping out of the current run fails loudly
        let gone = doc_with_hier(None);
        let fails = check(&gone, &good);
        assert_eq!(fails.len(), 2, "{fails:?}"); // staging params + gap
    }

    #[test]
    fn check_flags_missing_metrics() {
        let base = doc(Some(hotpath_report(1.0, 4.0)), Some(latency_report(10.0, 5.0)));
        let gone = doc(None, None);
        let fails = check(&gone, &base);
        assert_eq!(fails.len(), 3, "{fails:?}"); // ratio + two gap params
        // ...but a first run against an empty baseline passes
        assert!(check(&base, &empty()).is_empty());
    }
}
