//! One observability schema from both executors (the PR's acceptance
//! criterion): run the same 16-rank PAT all-reduce through the network
//! simulator and the threaded transport with tracing on, export both
//! timelines as Chrome trace-event JSON, re-parse them, and check the two
//! documents speak the same schema — same top-level shape, same
//! `schema_version`, identical field sets for every event kind they
//! share — and that the two executors account for the same traffic.

use std::collections::{BTreeMap, BTreeSet};

use patcol::core::{Algorithm, Collective};
use patcol::obs::{chrome_trace, ChannelTags, Trace, TraceRecorder, SCHEMA_VERSION};
use patcol::sched;
use patcol::sim::{self, CostModel, Topology};
use patcol::transport::{run_allreduce, TransportOptions};
use patcol::util::json::{self, Json};
use patcol::util::Rng;

const N: usize = 16;
const PER: usize = 32; // f32 elems per chunk

fn program() -> sched::Program {
    // Lifts to the fused pat+pat:1 composition — reduce-scatter phase then
    // all-gather phase through one program.
    sched::generate(
        Algorithm::Pat { aggregation: usize::MAX },
        Collective::AllReduce,
        N,
    )
    .unwrap()
}

fn tags() -> ChannelTags {
    let alg = Algorithm::Pat { aggregation: usize::MAX };
    let rsp = sched::generate(alg, Collective::ReduceScatter, N).unwrap();
    let agp = sched::generate(alg, Collective::AllGather, N).unwrap();
    ChannelTags::composed(sched::compose::Layout::of(&rsp, &agp, 1))
}

fn sim_trace(p: &sched::Program) -> Trace {
    let topo = Topology::flat(N, CostModel::ib_hdr_nic_bw());
    let mut rec = TraceRecorder::new();
    sim::simulate_observed(p, &topo, &CostModel::ib_hdr(), PER * 4, &mut rec).unwrap();
    rec.finish()
}

fn transport_trace(p: &sched::Program) -> Trace {
    let total = p.chunk_space() * PER;
    let mut rng = Rng::new(11);
    let inputs: Vec<Vec<f32>> = (0..N)
        .map(|_| {
            let mut v = vec![0f32; total];
            rng.fill_f32(&mut v);
            v
        })
        .collect();
    let opts = TransportOptions { trace: true, ..Default::default() };
    let (_, rep) = run_allreduce(p, &inputs, &opts).unwrap();
    rep.trace.expect("trace requested")
}

/// Export → pretty text → re-parse, i.e. exactly what a consumer reads.
fn exported(trace: &Trace) -> Json {
    json::parse(&chrome_trace(trace, &tags()).to_pretty()).unwrap()
}

/// Event schema of a Chrome trace document: for each `(ph, name)` kind,
/// the set of field keys it carries (args flattened as `args.*`).
/// Metadata (`ph == "M"`) records name processes/threads, not timeline
/// events, and are not part of the event schema.
fn schema_of(doc: &Json) -> BTreeMap<String, BTreeSet<String>> {
    let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let mut schema: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for e in evs {
        let obj = e.as_obj().unwrap();
        let ph = obj.get("ph").unwrap().as_str().unwrap();
        if ph == "M" {
            continue;
        }
        let name = obj.get("name").unwrap().as_str().unwrap();
        let keys = schema.entry(format!("{ph}:{name}")).or_default();
        for (k, v) in obj {
            if k == "args" {
                for ak in v.as_obj().unwrap().keys() {
                    keys.insert(format!("args.{ak}"));
                }
            } else {
                keys.insert(k.clone());
            }
        }
    }
    schema
}

#[test]
fn both_executors_emit_one_schema() {
    let p = program();
    let st = sim_trace(&p);
    let tt = transport_trace(&p);

    let sim_doc = exported(&st);
    let tp_doc = exported(&tt);

    // Top-level shape + stamped schema version, both documents.
    for doc in [&sim_doc, &tp_doc] {
        assert_eq!(
            doc.get("otherData")
                .and_then(|o| o.get("schema_version"))
                .and_then(|v| v.as_usize()),
            Some(SCHEMA_VERSION as usize)
        );
        assert!(doc.get("displayTimeUnit").is_some());
        assert!(!doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }

    let ss = schema_of(&sim_doc);
    let ts = schema_of(&tp_doc);

    // The core timeline kinds come out of both executors.
    for kind in ["X:send", "X:recv", "X:wire", "X:reduce"] {
        assert!(ss.contains_key(kind), "sim missing {kind}: {:?}", ss.keys());
        assert!(ts.contains_key(kind), "transport missing {kind}: {:?}", ts.keys());
    }
    // Pool occupancy is transport-only (the simulator has no buffer pool).
    assert!(ts.contains_key("C:pool live slots"));
    assert!(!ss.contains_key("C:pool live slots"));

    // Every kind both executors emit carries identical field sets — the
    // "identical schema" acceptance criterion.
    for (kind, sim_keys) in &ss {
        if let Some(tp_keys) = ts.get(kind) {
            assert_eq!(
                sim_keys, tp_keys,
                "field sets diverge for event kind {kind}"
            );
        }
    }

    // Same program on both executors ⇒ the counters must account for the
    // same traffic, message for message and byte for byte.
    let (s_tot, t_tot) = (st.totals(), tt.totals());
    assert_eq!(s_tot.msgs_sent, t_tot.msgs_sent);
    assert_eq!(s_tot.msgs_recv, t_tot.msgs_recv);
    assert_eq!(s_tot.bytes_sent, t_tot.bytes_sent);
    assert_eq!(s_tot.bytes_recv, t_tot.bytes_recv);
    assert!(s_tot.reduce_calls > 0 && t_tot.reduce_calls > 0);
}

/// Arena steady state, observed: with a warm shared
/// [`patcol::transport::ArenaCache`], the second run of the same
/// reduce-scatter performs zero datapath allocations — the report says so,
/// and the v2 trace counters (`allocs`, `arena_hw_bytes`) record the same
/// story per (rank, channel).
#[test]
fn steady_state_records_zero_allocs() {
    use patcol::transport::{run_reduce_scatter, ArenaCache};

    let p = sched::generate(
        Algorithm::Pat { aggregation: usize::MAX },
        Collective::ReduceScatter,
        N,
    )
    .unwrap();
    let total = p.chunk_space() * PER;
    let mut rng = Rng::new(23);
    let inputs: Vec<Vec<f32>> = (0..N)
        .map(|_| {
            let mut v = vec![0f32; total];
            rng.fill_f32(&mut v);
            v
        })
        .collect();
    let opts = TransportOptions {
        trace: true,
        arena: Some(ArenaCache::new()),
        ..Default::default()
    };

    let (out1, rep1) = run_reduce_scatter(&p, &inputs, &opts).unwrap();
    assert_eq!(rep1.arena_allocs, 1, "cold cache allocates exactly one arena");
    assert!(rep1.arena_bytes > 0);

    let (out2, rep2) = run_reduce_scatter(&p, &inputs, &opts).unwrap();
    assert_eq!(out1, out2, "warm run diverged");
    assert_eq!(rep2.arena_allocs, 0, "warm cache re-allocated the arena");
    assert_eq!(rep2.slots_allocated, 0, "steady state fell back to the heap");
    assert!(rep2.arena_hw_bytes > 0, "high-water mark not recorded");
    assert!(
        rep2.arena_hw_bytes <= rep2.arena_bytes,
        "high-water {} exceeds the arena footprint {}",
        rep2.arena_hw_bytes,
        rep2.arena_bytes
    );

    // The same facts flow through the trace counters (schema v2 fields).
    let trace = rep2.trace.expect("trace requested");
    let tot = trace.totals();
    assert_eq!(tot.allocs, 0, "trace counters saw steady-state allocations");
    assert!(tot.arena_hw_bytes > 0, "trace counters missing arena high-water");
}

#[test]
fn spans_are_well_formed_and_grouped() {
    let p = program();
    for trace in [sim_trace(&p), transport_trace(&p)] {
        let doc = exported(&trace);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let mut process_names = 0usize;
        for e in evs {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            match ph {
                "M" => {
                    if e.get("name").unwrap().as_str() == Some("process_name") {
                        process_names += 1;
                    }
                }
                "X" => {
                    // Perfetto needs pid/tid/ts/dur; durations are
                    // non-negative microseconds.
                    let pid = e.get("pid").unwrap().as_usize().unwrap();
                    assert!(pid < N);
                    assert!(e.get("tid").unwrap().as_usize().is_some());
                    assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
                    assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
                }
                "C" => {
                    assert!(e.get("args").unwrap().get("live").is_some());
                }
                other => panic!("unexpected phase {other:?}"),
            }
        }
        // One process-name record per rank: the rank → channel grouping.
        assert_eq!(process_names, N);
    }
}
