//! Channel-count sweep: C ∈ {1, 2, 4, 8} × payload size for a single
//! all-gather split across NCCL-style channels
//! ([`patcol::sched::channel::split`]) on the 256-rank tapered three-level
//! fat-tree.
//!
//! The question the first-class channel dimension answers: when does
//! splitting one collective across parallel connections pay? Each channel
//! is its own proxy stream and its own statically-hashed flow, so C
//! channels (a) spread a rank's traffic over the fabric's parallel
//! spines/cores instead of serializing behind one ECMP choice, and (b)
//! desynchronize, filling each other's link idle gaps. The price is C×
//! the per-message overhead. At latency-bound sizes the overhead wins and
//! C = 1 is best; at bandwidth-bound sizes on the tapered fabric the
//! spreading wins and C > 1 takes over — the crossover this bench records
//! as machine-readable JSON (`speedup_vs_single` per (C, size) row), the
//! same shape `allreduce_compose.rs` uses for the segment crossover.
//!
//! `--smoke` runs a minimal configuration (CI bench-rot guard); the
//! headline crossover assertion runs in the full configuration.

use patcol::core::{Algorithm, Collective};
use patcol::report::Report;
use patcol::sched::{self, channel};
use patcol::sim::{simulate, CostModel, Topology};
use patcol::util::json::Json;
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = if smoke { 64usize } else { 256usize };
    let topo =
        Topology::three_level(n, 8, 4, 4, 2, CostModel::ib_hdr_nic_bw(), 1.0, 0.25).unwrap();
    let cost = CostModel::ib_hdr();
    let channel_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    // Per-rank chunk payload before splitting; channel C moves 1/C-sized
    // sub-chunks of the same total.
    let totals: &[usize] = if smoke {
        &[4 << 20]
    } else {
        &[4 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20, 16 << 20]
    };
    let base = sched::generate(
        Algorithm::Pat { aggregation: usize::MAX },
        Collective::AllGather,
        n,
    )
    .unwrap();

    let mut report = Report::new("channel_sweep");
    report.param("nranks", Json::num(n as f64));
    report.param("topology", Json::str(topo.name.clone()));
    report.param("algorithm", Json::str(base.algorithm.clone()));
    report.param("smoke", Json::Bool(smoke));

    println!(
        "\nall-gather channels × size on {} (tapered top tier):",
        topo.name
    );
    let mut t = Table::new(["chunk/rank", "channels", "sub-chunk", "time", "vs C=1"]);
    let mut crossover_rows: Vec<Json> = Vec::new();
    // (largest size's single-channel time, best multi-channel time) for
    // the headline assertion.
    let mut headline: Option<(f64, f64)> = None;
    for &total in totals {
        let mut t1: Option<f64> = None;
        let mut best_multi = f64::INFINITY;
        for &c in channel_counts {
            let prog = channel::split(&base, c).unwrap();
            let sub = (total / c).max(1);
            let rep = simulate(&prog, &topo, &cost, sub).unwrap();
            if c == 1 {
                t1 = Some(rep.total_time);
            } else {
                best_multi = best_multi.min(rep.total_time);
            }
            let speedup = t1.map(|s| s / rep.total_time);
            t.row([
                fmt_bytes(total),
                format!("{c}"),
                fmt_bytes(sub),
                fmt_time_s(rep.total_time),
                speedup.map(|s| format!("{s:.2}x")).unwrap_or_default(),
            ]);
            report.rows.push(Json::obj(vec![
                ("total_bytes", Json::num(total as f64)),
                ("channels", Json::num(c as f64)),
                ("sub_chunk_bytes", Json::num(sub as f64)),
                ("time", Json::num(rep.total_time)),
                ("messages", Json::num(rep.messages as f64)),
                ("max_link_bytes", Json::num(rep.max_link_bytes as f64)),
            ]));
            if c > 1 {
                if let Some(seq) = t1 {
                    crossover_rows.push(Json::obj(vec![
                        ("total_bytes", Json::num(total as f64)),
                        ("channels", Json::num(c as f64)),
                        ("speedup_vs_single", Json::num(seq / rep.total_time)),
                    ]));
                }
            }
        }
        headline = Some((t1.unwrap(), best_multi));
    }
    print!("{}", t.render());
    report.param("crossover", Json::Arr(crossover_rows));

    // Headline (the acceptance row): at the bandwidth-bound extreme
    // (largest size in the sweep) the best multi-channel count beats the
    // single channel on the tapered fabric — parallel connections recruit
    // parallel links. Asserted on the full 256-rank configuration; the
    // smoke run records without asserting (different scale, same JSON).
    let (t_single, t_multi) = headline.unwrap();
    println!(
        "\nbest C>1 vs C=1 at {} per rank: {} vs {} ({:.2}x)",
        fmt_bytes(*totals.last().unwrap()),
        fmt_time_s(t_multi),
        fmt_time_s(t_single),
        t_single / t_multi
    );
    report.param("headline_speedup", Json::num(t_single / t_multi));
    if !smoke {
        assert!(
            t_multi < t_single,
            "multi-channel must pay at the bandwidth-bound extreme: {t_multi} !< {t_single}"
        );
    }
    report.save().unwrap();
}
