//! Small statistics helpers for the bench harness and reports.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean.abs()
        }
    }
}

/// Percentile over an already-sorted slice with linear interpolation.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean of ratios (used for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Simple least-squares linear fit `y = a + b x`, returns `(a, b, r2)`.
/// Used to classify measured step/latency curves as linear vs logarithmic.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if sxx == 0.0 || syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn linfit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn geomean_of_equal() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
