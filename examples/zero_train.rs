//! End-to-end driver: ZeRO-style data-parallel training with PAT
//! collectives on real gradient bytes — every layer of the stack composed:
//!
//!   L1 Pallas kernels (reduce, scale_add) + L2 jax transformer train-step
//!   → AOT HLO artifacts → L3 rust: per-rank grad computation via PJRT,
//!   PAT reduce-scatter of gradients (threaded transport, real bytes),
//!   sharded optimizer update via the Pallas scale_add artifact, PAT
//!   all-gather of updated parameters.
//!
//! Run `make artifacts` first, then:
//!
//!     cargo run --release --example zero_train -- [steps] [lr]
//!
//! Defaults: 150 steps, lr 0.25 (SGD, gradient-averaged). Writes the loss
//! curve to bench_results/zero_train.json and prints it; EXPERIMENTS.md
//! records a reference run.

use std::path::PathBuf;
use std::time::Instant;

use patcol::coordinator::{CommConfig, Communicator};
use patcol::core::{Algorithm, Result};
use patcol::report::Report;
use patcol::runtime::{ArtifactKind, PjrtContext, Registry};
use patcol::util::json::Json;
use patcol::util::Rng;

const NRANKS: usize = 8;

fn artifacts_dir() -> PathBuf {
    std::env::var("PATCOL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Synthetic corpus: next-token is a fixed affine map of the current token,
/// with a per-sequence random start — fully learnable structure.
fn make_batch(rng: &mut Rng, batch: usize, seq: usize, vocab: usize) -> Vec<i32> {
    let mut toks = Vec::with_capacity(batch * (seq + 1));
    for _ in 0..batch {
        let mut t = rng.below(vocab);
        for _ in 0..=seq {
            toks.push(t as i32);
            t = (t * 5 + 17) % vocab;
        }
    }
    toks
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(150);
    let lr: f32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.25);

    let dir = artifacts_dir();
    let ctx = PjrtContext::cpu()?;
    let reg = Registry::load(ctx, &dir)?;
    let meta = reg
        .meta("train_step")
        .ok_or_else(|| patcol::core::Error::Runtime(
            "no train_step artifact; run `make artifacts`".into(),
        ))?
        .clone();
    let nparams = meta.extra["params"];
    let batch = meta.extra["batch"];
    let seq = meta.extra["seq"];
    let vocab = meta.extra["vocab"];
    println!(
        "zero_train: {nparams} params, {NRANKS} ranks x batch {batch}, seq {seq}, vocab {vocab}, {steps} steps, lr {lr}"
    );

    // Shard geometry: pad to a lane-aligned multiple of NRANKS.
    let shard = {
        let s = nparams.div_ceil(NRANKS);
        s.div_ceil(128) * 128
    };
    let padded = shard * NRANKS;
    // The AOT pipeline emitted a scale_add artifact at exactly this size.
    let sa_meta = reg.pick_class(ArtifactKind::ScaleAdd, shard)?.clone();
    println!("shard {shard} elems (scale_add artifact n={})", sa_meta.n);

    // Initial parameters (identical on every rank, as after broadcast).
    let raw = std::fs::read(dir.join("init_params.f32"))?;
    let mut params: Vec<f32> = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    assert_eq!(params.len(), nparams);

    let train = reg.get("train_step")?;
    let sa = reg.get(&sa_meta.name)?;

    // PAT collectives over the threaded transport (scalar reduction on the
    // collective path; the Pallas kernels run the grad + update compute).
    let comm = Communicator::new(CommConfig {
        nranks: NRANKS,
        algorithm: Some(Algorithm::Pat { aggregation: 2 }),
        ..Default::default()
    })?;

    let mut rng = Rng::new(2026);
    let mut losses: Vec<f64> = Vec::with_capacity(steps);
    let (mut t_compute, mut t_rs, mut t_ag, mut t_opt) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let run_start = Instant::now();

    for step in 0..steps {
        // --- per-rank gradient computation (PJRT train_step artifact) ----
        let t0 = Instant::now();
        let mut rank_grads: Vec<Vec<f32>> = Vec::with_capacity(NRANKS);
        let mut loss_sum = 0f64;
        for _r in 0..NRANKS {
            let toks = make_batch(&mut rng, batch, seq, vocab);
            let plit = xla::Literal::vec1(&params);
            let tlit = xla::Literal::vec1(&toks)
                .reshape(&[batch as i64, (seq + 1) as i64])
                .map_err(|e| patcol::core::Error::Runtime(format!("{e:?}")))?;
            let outs = train.run_literals(&[plit, tlit])?;
            let loss = outs[0]
                .to_vec::<f32>()
                .map_err(|e| patcol::core::Error::Runtime(format!("{e:?}")))?[0];
            let mut grads = outs[1]
                .to_vec::<f32>()
                .map_err(|e| patcol::core::Error::Runtime(format!("{e:?}")))?;
            grads.resize(padded, 0.0); // pad for sharding
            loss_sum += loss as f64;
            rank_grads.push(grads);
        }
        t_compute += t0.elapsed().as_secs_f64();
        let loss_mean = loss_sum / NRANKS as f64;
        losses.push(loss_mean);

        // --- PAT reduce-scatter: each rank ends with its grad shard ------
        let t0 = Instant::now();
        let shards = comm.reduce_scatter(&rank_grads)?;
        t_rs += t0.elapsed().as_secs_f64();

        // --- sharded optimizer step (Pallas scale_add artifact) ----------
        // grads were summed over ranks; fold the 1/NRANKS average into lr.
        let t0 = Instant::now();
        let lr_eff = vec![lr / NRANKS as f32];
        let mut new_shards: Vec<Vec<f32>> = Vec::with_capacity(NRANKS);
        for (r, gshard) in shards.iter().enumerate() {
            let pshard = &params_padded(&params, padded)[r * shard..(r + 1) * shard];
            let dims = [sa_meta.n as i64];
            let mut p_in = pshard.to_vec();
            let mut g_in = gshard.clone();
            p_in.resize(sa_meta.n, 0.0);
            g_in.resize(sa_meta.n, 0.0);
            let out = sa.run_f32(&[(&p_in, &dims), (&g_in, &dims), (&lr_eff, &[1])])?;
            new_shards.push(out[0][..shard].to_vec());
        }
        t_opt += t0.elapsed().as_secs_f64();

        // --- PAT all-gather: everyone reassembles the full parameters ----
        let t0 = Instant::now();
        let gathered = comm.all_gather(&new_shards)?;
        t_ag += t0.elapsed().as_secs_f64();
        // all ranks agree byte-for-byte
        for r in 1..NRANKS {
            assert_eq!(gathered[r], gathered[0], "rank {r} diverged at step {step}");
        }
        params.copy_from_slice(&gathered[0][..nparams]);

        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {step:>4}  loss {loss_mean:.4}  (compute {t_compute:.1}s rs {t_rs:.2}s opt {t_opt:.2}s ag {t_ag:.2}s)"
            );
        }
    }

    let wall = run_start.elapsed().as_secs_f64();
    let first = losses.first().copied().unwrap_or(0.0);
    let last = losses.last().copied().unwrap_or(0.0);
    println!(
        "\ndone: loss {first:.4} -> {last:.4} over {steps} steps in {wall:.1}s \
         (compute {t_compute:.1}s, rs {t_rs:.2}s, opt {t_opt:.2}s, ag {t_ag:.2}s)"
    );
    if steps >= 20 {
        assert!(
            last < first * 0.8,
            "training did not converge: {first} -> {last}"
        );
    }

    let mut rep = Report::new("zero_train");
    rep.param("nranks", Json::num(NRANKS as f64));
    rep.param("params", Json::num(nparams as f64));
    rep.param("steps", Json::num(steps as f64));
    rep.param("lr", Json::num(lr as f64));
    rep.param("wall_s", Json::num(wall));
    rep.param("compute_s", Json::num(t_compute));
    rep.param("rs_s", Json::num(t_rs));
    rep.param("ag_s", Json::num(t_ag));
    for (i, l) in losses.iter().enumerate() {
        rep.row(vec![("step", Json::num(i as f64)), ("loss", Json::num(*l))]);
    }
    rep.save()?;
    Ok(())
}

/// Copy of params padded to the sharded length (cheap at this scale; the
/// perf-relevant paths are the collectives and the PJRT calls).
fn params_padded(params: &[f32], padded: usize) -> Vec<f32> {
    let mut v = params.to_vec();
    v.resize(padded, 0.0);
    v
}
