//! Schedule generation: the PAT algorithm and its baselines, all emitting a
//! common per-rank program IR ([`Program`]).
//!
//! One IR serves every consumer in the stack:
//! * [`verify`] — the reference executor (correctness, FIFO/deadlock checks,
//!   buffer-occupancy measurement),
//! * [`crate::transport`] — the threaded real-byte engine,
//! * [`crate::sim`] — the event-driven network simulator,
//! * the schedule explorer example (regenerates the paper's figures).
//!
//! Reduce-scatter programs are derived from all-gather programs by
//! [`Program::mirror`]: reverse time, flip send↔recv, reduce on receive.
//! This is exactly the paper's construction ("the reduce-scatter PAT
//! algorithm works the same way as all-gather, but with a reversed binomial
//! tree", communicating close dimensions first and executing the parallel
//! trees before the logarithmic part).
//!
//! [`hier`] adds the topology-aware tier: two-level schedules over a rank
//! [`Placement`] (intra-node tree, inter-node PAT among node leaders,
//! intra-node fan-out) generated through the placement-aware front-end
//! [`generate_placed`].

pub mod program;
pub mod tree;
pub mod ring;
pub mod bruck;
pub mod recursive;
pub mod pat;
pub mod hier;
pub mod verify;
pub mod explain;

pub use program::{Op, Program, ProgramStats};
pub use tree::{FarFirstTree, NearFirstTree};
pub use verify::{verify_program, OccupancyReport};

use crate::core::{Algorithm, Collective, Error, Placement, Result};

/// Default node size assumed when a hierarchical algorithm is requested
/// without an explicit placement (contiguous 8-rank nodes — the common
/// GPUs-per-server count).
pub const DEFAULT_RANKS_PER_NODE: usize = 8;

/// Generate a program for `algorithm` on `nranks`.
///
/// For reduce-scatter, every algorithm is the mirror of its all-gather
/// counterpart (recursive doubling mirrors to recursive halving).
/// Placement-aware algorithms ([`Algorithm::HierPat`]) fall back to
/// contiguous nodes of [`DEFAULT_RANKS_PER_NODE`]; use [`generate_placed`]
/// to supply the real rank placement.
pub fn generate(alg: Algorithm, coll: Collective, nranks: usize) -> Result<Program> {
    if nranks == 0 {
        return Err(Error::Schedule("nranks must be >= 1".into()));
    }
    if let Algorithm::HierPat { .. } = alg {
        let pl = Placement::uniform(nranks, DEFAULT_RANKS_PER_NODE)?;
        return generate_placed(alg, coll, &pl);
    }
    if !alg.supports(nranks) {
        return Err(Error::Unsupported(format!(
            "{alg} does not support nranks={nranks} (power-of-two required)"
        )));
    }
    let ag = match alg {
        Algorithm::Ring => ring::allgather(nranks),
        Algorithm::BruckNearFirst => bruck::allgather_near_first(nranks),
        Algorithm::BruckFarFirst => bruck::allgather_far_first(nranks),
        Algorithm::Recursive => recursive::allgather(nranks),
        Algorithm::Pat { aggregation } => pat::allgather(nranks, aggregation),
        Algorithm::PatAuto => {
            return Err(Error::Schedule(
                "PatAuto must be resolved by the tuner before generation".into(),
            ))
        }
        Algorithm::HierPat { .. } => unreachable!("handled above"),
    };
    Ok(match coll {
        Collective::AllGather => ag,
        Collective::ReduceScatter => ag.mirror(),
    })
}

/// Placement-aware generation front-end. [`Algorithm::HierPat`] builds its
/// two-level schedule from `placement`; flat algorithms ignore it (their
/// programs are placement-oblivious by construction).
pub fn generate_placed(
    alg: Algorithm,
    coll: Collective,
    placement: &Placement,
) -> Result<Program> {
    let nranks = placement.nranks();
    if nranks == 0 {
        return Err(Error::Schedule("placement must cover >= 1 rank".into()));
    }
    match alg {
        Algorithm::HierPat { aggregation } => {
            let ag = hier::allgather(placement, aggregation);
            Ok(match coll {
                Collective::AllGather => ag,
                Collective::ReduceScatter => ag.mirror(),
            })
        }
        _ => generate(alg, coll, nranks),
    }
}
