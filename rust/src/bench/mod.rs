//! Micro-benchmark harness (criterion is unavailable in this offline
//! environment, so the measurement substrate is built here): warmup,
//! auto-calibrated iteration counts, outlier-robust summaries, and a
//! consistent text+JSON reporting format shared by all `cargo bench`
//! targets.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Seconds per iteration.
    pub summary: Summary,
    pub iters_per_sample: usize,
    pub samples: usize,
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    /// Target duration for one sample batch.
    pub sample_target: Duration,
    /// Number of measured samples.
    pub samples: usize,
    /// Warmup duration.
    pub warmup: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            sample_target: Duration::from_millis(50),
            samples: 12,
            warmup: Duration::from_millis(100),
        }
    }
}

/// Fast preset for expensive bodies (simulator sweeps at scale).
pub fn quick() -> BenchOpts {
    BenchOpts {
        sample_target: Duration::from_millis(20),
        samples: 5,
        warmup: Duration::from_millis(20),
    }
}

/// Measure `f`, auto-calibrating the per-sample iteration count so each
/// sample runs for roughly `opts.sample_target`.
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> Measurement {
    // Warmup + calibration.
    let wstart = Instant::now();
    let mut calib_iters = 0usize;
    while wstart.elapsed() < opts.warmup || calib_iters == 0 {
        f();
        calib_iters += 1;
        if calib_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = wstart.elapsed().as_secs_f64() / calib_iters as f64;
    let iters = ((opts.sample_target.as_secs_f64() / per_iter.max(1e-9)).ceil() as usize)
        .clamp(1, 10_000_000);

    let mut samples = Vec::with_capacity(opts.samples);
    for _ in 0..opts.samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    Measurement {
        name: name.to_string(),
        summary: Summary::of(&samples),
        iters_per_sample: iters,
        samples: opts.samples,
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Measurement {
    pub fn per_iter(&self) -> f64 {
        self.summary.p50
    }

    /// `value / seconds` formatted as a rate (e.g. bytes/s).
    pub fn rate(&self, per_iter_units: f64) -> f64 {
        per_iter_units / self.per_iter()
    }

    pub fn line(&self) -> String {
        format!(
            "{:<42} p50 {:>12}  mean {:>12}  rsd {:>5.1}%  (n={} x {})",
            self.name,
            crate::util::table::fmt_time_s(self.summary.p50),
            crate::util::table::fmt_time_s(self.summary.mean),
            self.summary.rsd() * 100.0,
            self.samples,
            self.iters_per_sample,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let opts = BenchOpts {
            sample_target: Duration::from_millis(2),
            samples: 3,
            warmup: Duration::from_millis(2),
        };
        let mut acc = 0u64;
        let m = bench("noop-ish", &opts, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(m.per_iter() > 0.0);
        assert!(m.per_iter() < 1e-3);
        assert_eq!(m.samples, 3);
    }

    #[test]
    fn line_formats() {
        let m = Measurement {
            name: "x".into(),
            summary: Summary::of(&[1e-6, 1.1e-6, 0.9e-6]),
            iters_per_sample: 10,
            samples: 3,
        };
        assert!(m.line().contains("p50"));
    }
}
