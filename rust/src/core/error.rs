//! Crate-wide error type. Hand-rolled `Display`/`Error` impls (no
//! `thiserror` in this offline environment).

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    Config(String),
    Schedule(String),
    Transport(String),
    Verify(String),
    Runtime(String),
    Sim(String),
    /// Topology construction or placement-compatibility failure (e.g. a
    /// placement whose node straddles a leaf switch).
    Topology(String),
    Unsupported(String),
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "configuration error: {m}"),
            Error::Schedule(m) => write!(f, "schedule error: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Verify(m) => write!(f, "verification failed: {m}"),
            Error::Runtime(m) => write!(f, "runtime (PJRT) error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Topology(m) => write!(f, "topology error: {m}"),
            Error::Unsupported(m) => write!(f, "unsupported: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(
            Error::Topology("bad".into()).to_string(),
            "topology error: bad"
        );
        assert_eq!(
            Error::Config("oops".into()).to_string(),
            "configuration error: oops"
        );
        assert!(Error::Verify("x".into()).to_string().contains("verification"));
    }

    #[test]
    fn io_conversion() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("gone"));
    }
}
