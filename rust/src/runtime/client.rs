//! Thin, safe wrapper over the `xla` crate's PJRT CPU client.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All artifacts are lowered by jax with
//! `return_tuple=True`, so outputs are always a tuple literal which we
//! decompose.

use std::path::Path;
use std::sync::Arc;

use crate::core::{Error, Result};

/// A process-wide PJRT CPU context. Cheap to clone (Arc inside).
#[derive(Clone)]
pub struct PjrtContext {
    client: Arc<xla::PjRtClient>,
}

impl PjrtContext {
    /// Create (or fail with a runtime error wrapping the PJRT status).
    pub fn cpu() -> Result<PjrtContext> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtContext { client: Arc::new(client) })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: &Path, name: impl Into<String>) -> Result<Executable> {
        let name = name.into();
        let path_str = path.to_str().ok_or_else(|| {
            Error::Runtime(format!("non-utf8 artifact path {path:?}"))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path_str).map_err(|e| {
            Error::Runtime(format!("parse HLO text {path_str} ({name}): {e:?}"))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| {
            Error::Runtime(format!("compile artifact {name}: {e:?}"))
        })?;
        Ok(Executable { exe: Arc::new(exe), name })
    }
}

/// A compiled artifact. Cheap to clone; `run_f32` is safe to call from
/// multiple threads (PJRT CPU executables are thread-safe).
#[derive(Clone)]
pub struct Executable {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub name: String,
}

impl Executable {
    /// Execute with 1-D f32 inputs (each reshaped to the given dims) and
    /// return all tuple outputs as flat f32 vectors.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data);
            let lit = if dims.len() == 1 && dims[0] as usize == data.len() {
                lit
            } else {
                lit.reshape(dims)
                    .map_err(|e| Error::Runtime(format!("{}: reshape: {e:?}", self.name)))?
            };
            lits.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| Error::Runtime(format!("{}: execute: {e:?}", self.name)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("{}: to_literal: {e:?}", self.name)))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("{}: output not a tuple: {e:?}", self.name)))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(
                p.to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("{}: to_vec: {e:?}", self.name)))?,
            );
        }
        Ok(out)
    }

    /// Execute with pre-built literals (for mixed dtypes, e.g. token ids).
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| Error::Runtime(format!("{}: execute: {e:?}", self.name)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("{}: to_literal: {e:?}", self.name)))?;
        lit.to_tuple()
            .map_err(|e| Error::Runtime(format!("{}: output not a tuple: {e:?}", self.name)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The PJRT CPU client must come up when a real backend is linked in.
    /// (Artifact loading is exercised by integration tests once
    /// `make artifacts` has produced them.) With the offline `xla` stub the
    /// client is unavailable and construction must fail with a clean error.
    #[test]
    fn cpu_client_boots_or_reports_unavailable() {
        match PjrtContext::cpu() {
            Ok(ctx) => {
                assert_eq!(ctx.platform_name(), "cpu");
                assert!(ctx.device_count() >= 1);
            }
            Err(e) => {
                // Offline stub build: a clean "unavailable" error, no panic.
                assert!(e.to_string().contains("unavailable"), "{e}");
            }
        }
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let Ok(ctx) = PjrtContext::cpu() else {
            eprintln!("skipping: PJRT backend unavailable");
            return;
        };
        let err = ctx
            .load_hlo_text(Path::new("/nonexistent/foo.hlo.txt"), "foo")
            .unwrap_err();
        assert!(err.to_string().contains("foo"));
    }
}

impl std::fmt::Debug for PjrtContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtContext")
            .field("platform", &self.platform_name())
            .finish()
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").field("name", &self.name).finish()
    }
}
