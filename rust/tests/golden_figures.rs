//! Golden tests pinning the exact schedules of the paper's figures
//! (F1–F11 in DESIGN.md). Any change to the generators that alters these
//! schedules is a deliberate, reviewed event.

use patcol::core::Collective;
use patcol::sched::program::Message;
use patcol::sched::{bruck, explain, pat};

/// Compact encoding of rank 0's view of each step: (src->dst, chunks).
fn rank0_messages(msgs: &[Message]) -> Vec<(usize, usize, Vec<usize>)> {
    msgs.iter()
        .filter(|m| m.src == 0)
        .map(|m| (m.src, m.dst, m.chunks.clone()))
        .collect()
}

/// Fig. 1 — classic Bruck, 8 ranks: rank 0 sends 1, 2, 4 chunks to peers
/// at distance 1, 2, 4 (payload and distance grow together).
#[test]
fn fig1_bruck_near_first() {
    let p = bruck::allgather_near_first(8);
    assert_eq!(p.steps, 3);
    let got = rank0_messages(&p.messages());
    assert_eq!(
        got,
        vec![
            (0, 1, vec![0]),
            (0, 2, vec![0, 7]),
            (0, 4, vec![0, 7, 6, 5]),
        ]
    );
}

/// Fig. 2 — the same schedule decomposes into one binomial tree per root.
#[test]
fn fig2_per_root_trees() {
    let p = bruck::allgather_near_first(8);
    // chunk 0's tree: reached offsets double every step
    let mut holders = vec![0usize];
    for (_, msgs) in p.rounds() {
        let mut new = Vec::new();
        for m in &msgs {
            if m.chunks.contains(&0) {
                assert!(holders.contains(&m.src), "sender {} lacks chunk 0", m.src);
                new.push(m.dst);
            }
        }
        holders.extend(new);
    }
    holders.sort_unstable();
    assert_eq!(holders, (0..8).collect::<Vec<_>>());
}

/// Fig. 3 — reversed dimensions: distances shrink 4, 2, 1 while payloads
/// grow 1, 2, 4.
#[test]
fn fig3_bruck_far_first() {
    let p = bruck::allgather_far_first(8);
    assert_eq!(p.steps, 3);
    let got = rank0_messages(&p.messages());
    assert_eq!(
        got,
        vec![
            (0, 4, vec![0]),
            (0, 2, vec![0, 4]),
            (0, 1, vec![0, 6, 4, 2]),
        ]
    );
}

/// Fig. 4 — truncated trees on 7 ranks: per-step payloads 1, 2, 3.
#[test]
fn fig4_truncated_7() {
    let p = bruck::allgather_far_first(7);
    assert_eq!(p.steps, 3);
    let got = rank0_messages(&p.messages());
    assert_eq!(got[0], (0, 4, vec![0]));
    assert_eq!(got[1], (0, 2, vec![0, 3]));
    assert_eq!(got[2], (0, 1, vec![0, 5, 3]));
    let total: usize = got.iter().map(|(_, _, c)| c.len()).sum();
    assert_eq!(total, 6); // n-1 chunk transfers per rank
}

/// Fig. 5 — PAT 8 ranks, aggregation 2: the 4-chunk distance-1 round of
/// Fig. 3 splits into two 2-chunk rounds (4 steps total).
#[test]
fn fig5_pat_8_agg2() {
    let p = pat::allgather(8, 2);
    assert_eq!(p.steps, 4);
    let got = rank0_messages(&p.messages());
    assert_eq!(got[0], (0, 4, vec![0]));
    assert_eq!(got[1], (0, 2, vec![0, 4]));
    // linear phase: one edge per parallel tree per round, 2 chunks each
    assert_eq!(got[2], (0, 1, vec![6, 2]));
    assert_eq!(got[3], (0, 1, vec![0, 4]));
}

/// Fig. 6 — phase split: 1 logarithmic step + 3 linear steps.
#[test]
fn fig6_phases() {
    assert_eq!(pat::phase_counts(8, 2), (1, 3));
    let txt = explain::render_pat_tree(8, 2);
    assert!(txt.contains("1 logarithmic + 3 linear"), "{txt}");
}

/// Figs. 7-9 — 16 ranks with 8/4/2 trees: 4/5/8 steps.
#[test]
fn fig7_8_9_tree_counts() {
    assert_eq!(pat::allgather(16, 8).steps, 4);
    assert_eq!(pat::allgather(16, 4).steps, 5);
    assert_eq!(pat::allgather(16, 2).steps, 8);
    assert_eq!(pat::phase_counts(16, 8), (3, 1));
    assert_eq!(pat::phase_counts(16, 4), (2, 3));
    assert_eq!(pat::phase_counts(16, 2), (1, 7));
}

/// Fig. 10 — fully linear: 8 ranks, 7 steps, far-first then progressively
/// closer; every transfer is a single full chunk.
#[test]
fn fig10_fully_linear() {
    let p = pat::allgather(8, 1);
    assert_eq!(p.steps, 7);
    let got = rank0_messages(&p.messages());
    let dists: Vec<usize> = got.iter().map(|(_, d, _)| *d).collect();
    // DFS pre-order, far child first: 0->4, then subtree of 4, then near.
    assert_eq!(dists, vec![4, 2, 1, 1, 2, 1, 1]);
    assert!(got.iter().all(|(_, _, c)| c.len() == 1));
    // first transfer is the farthest child of the root
    assert_eq!(got[0].2, vec![0]);
}

/// Fig. 11 — reduce-scatter is the exact mirror: same messages with
/// src/dst swapped, in reverse step order, reduce on receive.
#[test]
fn fig11_rs_mirror() {
    let ag = pat::allgather(8, 2);
    let rs = pat::reduce_scatter(8, 2);
    assert_eq!(rs.collective, Collective::ReduceScatter);
    let mut ag_msgs = ag.messages();
    let rs_msgs = rs.messages();
    assert_eq!(ag_msgs.len(), rs_msgs.len());
    // reverse ag step order and flip direction -> must equal rs messages
    let max_step = ag.steps - 1;
    for m in &mut ag_msgs {
        std::mem::swap(&mut m.src, &mut m.dst);
        m.step = max_step - m.step;
    }
    ag_msgs.sort_by_key(|m| (m.step, m.src));
    for (a, b) in ag_msgs.iter().zip(&rs_msgs) {
        assert_eq!((a.src, a.dst, &a.chunks, a.step), (b.src, b.dst, &b.chunks, b.step));
    }
}

/// The rendered figures (text) stay stable for the explorer example.
#[test]
fn rendered_text_stable() {
    let p = pat::allgather(8, 2);
    let steps = explain::render_steps(&p);
    assert!(steps.contains("pat(a=2) / all_gather on 8 ranks — 4 steps"));
    assert!(steps.contains("0 -> 4"));
    let rank0 = explain::render_rank(&p, 0);
    assert!(rank0.contains("[s0] send -> 4: [0]"));
}
