//! Minimal CLI argument parser (clap is unavailable offline): a
//! subcommand, positional operands, plus `--key value` / `--flag` pairs
//! with typed accessors and generated usage text.

use std::collections::BTreeMap;

use crate::core::{Error, Result};
use crate::coordinator::config::parse_bytes;

/// Parsed command line: subcommand + positionals + options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first token is the subcommand; `--key value`
    /// pairs and bare `--flag`s follow. Bare tokens outside an option
    /// position are positional operands (`patcol analyze TRACE.json`),
    /// in order.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut positional = Vec::new();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                positional.push(tok);
                continue;
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    opts.insert(key.to_string(), it.next().unwrap());
                }
                _ => flags.push(key.to_string()),
            }
        }
        Ok(Args { command, positional, opts, flags })
    }

    /// Positional operands, in command-line order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.opts.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, name: &str) -> Option<String> {
        self.opts.get(name).cloned()
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: bad integer {v:?}"))),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: bad float {v:?}"))),
        }
    }

    /// Parse a byte size (`--size 1MiB`).
    pub fn bytes(&self, name: &str, default: usize) -> Result<usize> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => parse_bytes(v),
        }
    }

    /// Comma-separated list of usizes (`--ranks 8,16,32`).
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.opts.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| Error::Config(format!("--{name}: bad integer {t:?}")))
                })
                .collect(),
        }
    }

    /// Comma-separated byte sizes (`--sizes 1KiB,64KiB,4MiB`).
    pub fn bytes_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.opts.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v.split(',').map(|t| parse_bytes(t.trim())).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = args("run --ranks 16 --alg pat:2 --verbose --size 4KiB");
        assert_eq!(a.command, "run");
        assert_eq!(a.usize("ranks", 0).unwrap(), 16);
        assert_eq!(a.str("alg", ""), "pat:2");
        assert!(a.flag("verbose"));
        assert_eq!(a.bytes("size", 0).unwrap(), 4096);
    }

    #[test]
    fn lists() {
        let a = args("sweep --ranks 8,16,32 --sizes 1KiB,1MiB");
        assert_eq!(a.usize_list("ranks", &[]).unwrap(), vec![8, 16, 32]);
        assert_eq!(a.bytes_list("sizes", &[]).unwrap(), vec![1024, 1 << 20]);
    }

    #[test]
    fn defaults() {
        let a = args("run");
        assert_eq!(a.usize("ranks", 8).unwrap(), 8);
        assert_eq!(a.str("alg", "pat_auto"), "pat_auto");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn collects_positionals() {
        let a = args("analyze trace.json --json --ranks 16");
        assert_eq!(a.positional(), ["trace.json"]);
        assert!(a.flag("json"));
        assert_eq!(a.usize("ranks", 0).unwrap(), 16);
        // an option value is consumed by its option, not made positional
        let a = args("run --alg pat extra.json");
        assert_eq!(a.str("alg", ""), "pat");
        assert_eq!(a.positional(), ["extra.json"]);
        assert!(args("run").positional().is_empty());
    }
}
