//! In-process transport: executes schedule programs with real bytes moving
//! between rank threads — the "one rank per node" runtime of the paper,
//! collapsed onto one host.
//!
//! * [`engine`] — one OS thread per rank, FIFO channels per directed pair,
//!   blocking receives, non-blocking sends (the NCCL model where senders
//!   write into pre-mapped remote staging buffers). Wires carry
//!   `(offset, len)` descriptors into the shared arena, not owned
//!   vectors, and `drive_channels` batches every ready send per
//!   scheduler wakeup.
//! * [`arena`] — the preallocated page-aligned allocation behind the
//!   whole datapath (wire regions + staging slots); a per-communicator
//!   [`ArenaCache`] makes the steady-state path allocation-free.
//! * [`buffers`] — the bounded intermediate-buffer pool, carved from the
//!   arena. PAT's defining constraint is that staging/accumulator space
//!   is limited; the pool enforces the bound and records peak occupancy
//!   (paper claim P3).
//! * [`datapath`] — the receive-side reduction: either a pure-rust
//!   lane-chunked scalar kernel or the AOT-compiled Pallas kernel via the
//!   sharded PJRT service ([`crate::runtime::PjrtService`]).
//! * [`delivery`] — the adversarial delivery layer: a [`DeliveryPolicy`]
//!   hook over the per-(src, dst, channel) connection FIFOs (eager by
//!   default) with deterministic virtual-time decision points, used by
//!   [`crate::adversary`] to explore, shrink, and replay perturbed
//!   schedules against this engine.
//!
//! With [`TransportOptions::trace`] set, every rank thread keeps a
//! lock-free [`crate::obs::FlightRecorder`] ring (shared `Instant`
//! origin, merged into [`TransportReport::trace`] at join): op spans,
//! wire post→match windows, whole-thread park intervals attributed to
//! each blocked channel, buffer-pool occupancy samples, and
//! reduce-kernel invocations — the same [`crate::obs`] schema the
//! simulator emits. A watchdog recv timeout dumps the recorder's tail
//! plus a per-channel blame report (blocked step, peer, pending FIFO
//! depth), which names the deadlock instead of just reporting it.

pub mod arena;
pub mod engine;
pub mod buffers;
pub mod datapath;
pub mod delivery;

pub use arena::{Arena, ArenaCache, ArenaLease};
pub use buffers::{BufferPool, Slot};
pub use datapath::DataPath;
pub use delivery::{Decision, DeliveryFactory, DeliveryPolicy, EagerDelivery, Verdict};
pub use engine::{
    run_allgather, run_allgather_into, run_allreduce, run_allreduce_batch, run_reduce_scatter,
    TransportOptions, TransportReport,
};
