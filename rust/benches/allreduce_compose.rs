//! Composed all-reduce: phase pair × segment count × payload size on the
//! 256-rank tapered three-level fat-tree.
//!
//! The question the `sched/compose` subsystem answers: once all-reduce is
//! one fused RS∘AG program, how much does segment pipelining buy, and
//! where? Sequential composition (`:1`) serializes the full 2·log(n)
//! round chain at full round sizes; `S` segments quarter the rounds and
//! overlap each segment's all-gather with the next segment's
//! reduce-scatter, and each segment is its own NCCL-style channel with
//! its own statically-hashed flows. At latency-to-mid payloads the
//! overlapping channels fill each other's link idle gaps; at
//! bandwidth-bound payloads the overlap gain fades (both phases saturate
//! the same tapered core) but the per-channel path spreading keeps
//! pipelining ahead — under the channel-salted router the advantage
//! peaks mid-band (~1.2× at 1 MiB/rank) and narrows at the extremes.
//! The JSON report records the whole sweep so the shape is
//! machine-readable; the headline row is asserted.
//!
//! `--smoke` runs a minimal configuration (CI bench-rot guard).

use patcol::core::{Algorithm, Collective, PhaseAlg};
use patcol::report::Report;
use patcol::sched;
use patcol::sim::{simulate, CostModel, Topology};
use patcol::util::json::Json;
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = if smoke { 64usize } else { 256usize };
    let topo =
        Topology::three_level(n, 8, 4, 4, 2, CostModel::ib_hdr_nic_bw(), 1.0, 0.25).unwrap();
    let cost = CostModel::ib_hdr();

    const PAT: PhaseAlg = PhaseAlg::Pat { aggregation: usize::MAX };
    const RING: PhaseAlg = PhaseAlg::Ring;
    let pairs: &[(PhaseAlg, PhaseAlg)] = if smoke {
        &[(PAT, PAT)]
    } else {
        &[(PAT, PAT), (PAT, RING), (RING, RING)]
    };
    let segment_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    // Total payload per rank; per-chunk bytes = total / (n × segments).
    let totals: &[usize] = if smoke {
        &[64 << 10]
    } else {
        &[16 << 10, 64 << 10, 128 << 10, 256 << 10, 1 << 20, 4 << 20, 16 << 20]
    };

    let mut report = Report::new("allreduce_compose");
    report.param("nranks", Json::num(n as f64));
    report.param("topology", Json::str(topo.name.clone()));
    report.param("smoke", Json::Bool(smoke));

    println!(
        "\nall-reduce pair × segments × size on {} (tapered top tier):",
        topo.name
    );
    let mut t = Table::new(["pair", "total/rank", "segments", "chunk", "time"]);
    // (pair spec, total) -> time at segments=1, for crossover detection.
    let mut crossover_rows: Vec<Json> = Vec::new();
    for &(rs, ag) in pairs {
        let pair_spec = format!("{}+{}", rs.spec(), ag.spec());
        for &total in totals {
            let mut t_seq: Option<f64> = None;
            for &segments in segment_counts {
                let chunk = (total / (n * segments)).max(1);
                let alg = Algorithm::Compose { rs, ag, segments };
                let prog = sched::generate(alg, Collective::AllReduce, n).unwrap();
                let rep = simulate(&prog, &topo, &cost, chunk).unwrap();
                if segments == 1 {
                    t_seq = Some(rep.total_time);
                }
                t.row([
                    pair_spec.clone(),
                    fmt_bytes(total),
                    format!("{segments}"),
                    fmt_bytes(chunk),
                    fmt_time_s(rep.total_time),
                ]);
                report.rows.push(Json::obj(vec![
                    ("pair", Json::str(pair_spec.clone())),
                    ("total_bytes", Json::num(total as f64)),
                    ("segments", Json::num(segments as f64)),
                    ("chunk_bytes", Json::num(chunk as f64)),
                    ("time", Json::num(rep.total_time)),
                    ("messages", Json::num(rep.messages as f64)),
                ]));
                if segments > 1 {
                    if let Some(seq) = t_seq {
                        crossover_rows.push(Json::obj(vec![
                            ("pair", Json::str(pair_spec.clone())),
                            ("total_bytes", Json::num(total as f64)),
                            ("segments", Json::num(segments as f64)),
                            ("speedup_vs_sequential", Json::num(seq / rep.total_time)),
                        ]));
                    }
                }
            }
        }
    }
    print!("{}", t.render());
    report.param("crossover", Json::Arr(crossover_rows));

    // Headline (the acceptance row): pipelined pat+pat:4 beats the
    // sequential composition at a small-to-mid payload (64 KiB per rank).
    // Margins measured on this deterministic simulator with per-channel
    // ECMP salts (segments are channels and spread over distinct
    // spines/cores, which widens the win over the pre-channel router):
    // +9.8% at n=256, +24.5% at the n=64 smoke scale — both strict, so
    // the assert holds in smoke mode too.
    let total = 64 << 10;
    let seq = {
        let p = sched::generate(
            Algorithm::Compose { rs: PAT, ag: PAT, segments: 1 },
            Collective::AllReduce,
            n,
        )
        .unwrap();
        simulate(&p, &topo, &cost, total / n).unwrap().total_time
    };
    let piped = {
        let p = sched::generate(
            Algorithm::Compose { rs: PAT, ag: PAT, segments: 4 },
            Collective::AllReduce,
            n,
        )
        .unwrap();
        simulate(&p, &topo, &cost, total / (n * 4)).unwrap().total_time
    };
    println!(
        "\npat+pat:4 vs pat+pat:1 at {} per rank: {} vs {} ({:.2}x)",
        fmt_bytes(total),
        fmt_time_s(piped),
        fmt_time_s(seq),
        seq / piped
    );
    report.param("headline_speedup", Json::num(seq / piped));
    assert!(
        piped < seq,
        "pipelining must pay at {} per rank: {piped} !< {seq}",
        fmt_bytes(total)
    );
    report.save().unwrap();
}
