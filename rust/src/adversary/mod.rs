//! Adversarial delivery: a schedule-exploration harness that breaks the
//! transport on purpose.
//!
//! The threaded transport normally delivers each connection's messages
//! eagerly in FIFO order, which exercises exactly one of the many
//! arrival schedules a real network can produce. This module drives the
//! **real** transport (`crate::transport::engine`, not a model of it)
//! through the [`crate::transport::delivery`] hook with policies that
//! deliberately pick hostile schedules:
//!
//! - **delay** — seeded random holds at decision points, deepening the
//!   per-connection FIFOs and permuting cross-channel arrival order;
//! - **reorder** — delay plus in-connection reordering *attempts*
//!   (clamped to FIFO order by the transport's ordering guard unless
//!   the `fifo-guard-off` mutation sentinel is armed);
//! - **pressure** — hold every head once, maximising simultaneous slot
//!   occupancy to probe the pool bound at its worst step;
//! - **dpor** — DPOR-lite: the episode index is a bit-vector that
//!   systematically flips defer/deliver at hashed decision points,
//!   enumerating cross-channel interleavings without randomness.
//!
//! An episode ([`explore::run_episode`]) runs one workload under one
//! policy with the sound slot capacity enforced, then compares the
//! result bit-exactly against the reference. Failures are blamed to
//! `(rank, channel, step, kind)` ([`Blame`]) and the policy's recorded
//! perturbation list is shrunk by greedy delta-debugging
//! ([`shrink::shrink`]) to a minimal deviation list that still
//! reproduces the same blame. The result is a [`ReplayTrace`]: a small
//! JSON document that replays deterministically on any machine because
//! deviations key on the per-connection match index (deterministic
//! virtual time), not on wall-clock arrival.
//!
//! Mutation sentinels (`crate::transport::delivery::sentinel`) disable
//! one transport invariant at a time — the FIFO-ordering guard or one
//! slot release — so the test suite can assert the explorer actually
//! *finds* the bugs this harness exists for, not merely that healthy
//! code survives it. Sentinels exist only under `cfg(test)` or the
//! `adversary` feature; release builds cannot arm them.
//!
//! Entry points: `patcol adversary` (episode sweeps, `--replay` for
//! saved traces), [`explore::explore`] and [`replay`] from code.

pub mod explore;
pub mod policy;
pub mod shrink;

#[cfg(test)]
mod tests;

pub use explore::{
    explore, parse_blame, run_episode, Blame, EpisodeOutcome, ExploreReport, Failure, Workload,
};
pub use policy::{DevKind, Deviation, PolicySpec, Preset};
pub use shrink::{replay_pinned, shrink as shrink_failure, ShrinkResult};

use crate::core::{AlgSpec, Collective, Error, Result};
use crate::util::json::{self, Json};

/// Parse a collective name as accepted by traces and the CLI.
pub fn parse_collective(s: &str) -> Result<Collective> {
    match s.trim() {
        "all_gather" | "allgather" | "ag" => Ok(Collective::AllGather),
        "reduce_scatter" | "reducescatter" | "rs" => Ok(Collective::ReduceScatter),
        "all_reduce" | "allreduce" | "ar" => Ok(Collective::AllReduce),
        other => Err(Error::Config(format!("unknown collective {other:?}"))),
    }
}

/// Trace-format version, bumped on any incompatible field change.
pub const TRACE_SCHEMA: usize = 1;

/// A shrunk, replayable counterexample: workload coordinates, the
/// minimal deviation list, the sentinel (if one was armed when it was
/// found), and the blame that replay must reproduce bit-exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayTrace {
    pub workload: Workload,
    /// Policy spec that found the failure (provenance only — replay is
    /// pinned and never consults it).
    pub policy: String,
    /// Episode index the failure was found at.
    pub episode: u64,
    /// Mutation sentinel armed when the trace was captured, by name.
    pub sentinel: Option<String>,
    pub deviations: Vec<Deviation>,
    pub blame: Blame,
    /// Deviations before shrinking (provenance).
    pub initial_deviations: usize,
    /// Replay trials the shrinker spent (provenance).
    pub shrink_trials: usize,
}

impl ReplayTrace {
    pub fn new(w: &Workload, policy: &PolicySpec, episode: u64, shrunk: &ShrinkResult) -> ReplayTrace {
        ReplayTrace {
            workload: w.clone(),
            policy: policy.spec(),
            episode,
            sentinel: active_sentinel_name(),
            deviations: shrunk.deviations.clone(),
            blame: shrunk.blame.clone(),
            initial_deviations: shrunk.initial,
            shrink_trials: shrunk.trials,
        }
    }

    pub fn to_json(&self) -> Json {
        let w = &self.workload;
        Json::obj(vec![
            ("schema", Json::num(TRACE_SCHEMA as f64)),
            (
                "workload",
                Json::obj(vec![
                    ("collective", Json::str(w.collective.as_str())),
                    ("alg", Json::str(w.spec.spec())),
                    ("nranks", Json::num(w.nranks as f64)),
                    ("elems", Json::num(w.elems as f64)),
                    ("seed", Json::num(w.seed as f64)),
                ]),
            ),
            ("policy", Json::str(self.policy.as_str())),
            ("episode", Json::num(self.episode as f64)),
            (
                "sentinel",
                match &self.sentinel {
                    Some(s) => Json::str(s.as_str()),
                    None => Json::Null,
                },
            ),
            (
                "deviations",
                Json::arr(self.deviations.iter().map(|d| {
                    let arg = match d.kind {
                        DevKind::Hold { cycles } => cycles as f64,
                        DevKind::Skip { depth } => depth as f64,
                    };
                    Json::obj(vec![
                        ("rank", Json::num(d.rank as f64)),
                        ("src", Json::num(d.src as f64)),
                        ("channel", Json::num(d.channel as f64)),
                        ("nth", Json::num(d.nth as f64)),
                        ("kind", Json::str(d.kind.name())),
                        ("arg", Json::num(arg)),
                    ])
                })),
            ),
            (
                "blame",
                Json::obj(vec![
                    ("rank", Json::num(self.blame.rank as f64)),
                    ("channel", Json::num(self.blame.channel as f64)),
                    ("step", Json::num(self.blame.step as f64)),
                    ("kind", Json::str(self.blame.kind.as_str())),
                ]),
            ),
            (
                "provenance",
                Json::obj(vec![
                    ("initial_deviations", Json::num(self.initial_deviations as f64)),
                    ("shrink_trials", Json::num(self.shrink_trials as f64)),
                ]),
            ),
        ])
    }

    pub fn from_json(doc: &Json) -> Result<ReplayTrace> {
        let bad = |what: &str| Error::Config(format!("replay trace: missing or bad {what}"));
        let schema = doc.get("schema").and_then(Json::as_usize).ok_or_else(|| bad("schema"))?;
        if schema != TRACE_SCHEMA {
            return Err(Error::Config(format!(
                "replay trace schema {schema} unsupported (this build reads {TRACE_SCHEMA})"
            )));
        }
        let w = doc.get("workload").ok_or_else(|| bad("workload"))?;
        let field = |obj: &Json, key: &str| -> Result<usize> {
            obj.get(key).and_then(Json::as_usize).ok_or_else(|| bad(key))
        };
        let workload = Workload {
            collective: parse_collective(
                w.get("collective").and_then(Json::as_str).ok_or_else(|| bad("collective"))?,
            )?,
            spec: AlgSpec::parse(w.get("alg").and_then(Json::as_str).ok_or_else(|| bad("alg"))?)?,
            nranks: field(w, "nranks")?,
            elems: field(w, "elems")?,
            seed: field(w, "seed")? as u64,
        };
        let deviations = doc
            .get("deviations")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("deviations"))?
            .iter()
            .map(|d| -> Result<Deviation> {
                let arg = field(d, "arg")?;
                let kind = match d.get("kind").and_then(Json::as_str) {
                    Some("hold") => DevKind::Hold { cycles: arg as u32 },
                    Some("skip") => DevKind::Skip { depth: arg },
                    other => {
                        return Err(Error::Config(format!(
                            "replay trace: unknown deviation kind {other:?}"
                        )))
                    }
                };
                Ok(Deviation {
                    rank: field(d, "rank")?,
                    src: field(d, "src")?,
                    channel: field(d, "channel")?,
                    nth: field(d, "nth")? as u64,
                    kind,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let b = doc.get("blame").ok_or_else(|| bad("blame"))?;
        let blame = Blame {
            rank: field(b, "rank")?,
            channel: field(b, "channel")?,
            step: field(b, "step")?,
            kind: b.get("kind").and_then(Json::as_str).ok_or_else(|| bad("blame kind"))?.to_string(),
        };
        let prov = doc.get("provenance");
        Ok(ReplayTrace {
            workload,
            policy: doc.get("policy").and_then(Json::as_str).unwrap_or("").to_string(),
            episode: doc.get("episode").and_then(Json::as_usize).unwrap_or(0) as u64,
            sentinel: doc
                .get("sentinel")
                .and_then(Json::as_str)
                .map(str::to_string),
            deviations,
            blame,
            initial_deviations: prov
                .and_then(|p| p.get("initial_deviations"))
                .and_then(Json::as_usize)
                .unwrap_or(0),
            shrink_trials: prov
                .and_then(|p| p.get("shrink_trials"))
                .and_then(Json::as_usize)
                .unwrap_or(0),
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<ReplayTrace> {
        let text = std::fs::read_to_string(path)?;
        ReplayTrace::from_json(&json::parse(&text)?)
    }
}

/// Name of the currently armed mutation sentinel, when sentinels exist
/// in this build.
fn active_sentinel_name() -> Option<String> {
    #[cfg(any(test, feature = "adversary"))]
    {
        return crate::transport::delivery::sentinel::active().map(|s| s.name().to_string());
    }
    #[cfg(not(any(test, feature = "adversary")))]
    None
}

/// Replay a saved trace: arm its sentinel (if any), pin its deviations,
/// run the workload, and return the failure it produces. The caller
/// compares the returned blame against [`ReplayTrace::blame`] — the
/// golden-trace test and `patcol adversary --replay` both require exact
/// equality.
pub fn replay(trace: &ReplayTrace) -> Result<Option<Failure>> {
    #[cfg(any(test, feature = "adversary"))]
    {
        use crate::transport::delivery::sentinel;
        let _armed = match trace.sentinel.as_deref() {
            Some(name) => Some(sentinel::arm(sentinel::Sentinel::parse(name)?)),
            None => None,
        };
        return replay_pinned(&trace.workload, &trace.deviations);
    }
    #[cfg(not(any(test, feature = "adversary")))]
    {
        if let Some(name) = &trace.sentinel {
            return Err(Error::Config(format!(
                "replay trace arms mutation sentinel {name:?}; rebuild with --features adversary"
            )));
        }
        replay_pinned(&trace.workload, &trace.deviations)
    }
}
