//! Bruck all-gather in both dimension orders (paper Figs. 1–4).
//!
//! Classic (nearest-dimension-first) Bruck doubles both the distance and the
//! payload every step: the last step sends half of the total data to the
//! most distant peer — the behaviour that collapses on static-routed /
//! tapered fabrics and motivates PAT. Reversing the dimension order fixes
//! the distance profile but makes the payload non-contiguous (the data sent
//! to a peer comes from ranks with stride `2^(d+1)`), which is where PAT's
//! bounded aggregation picks up.

use crate::core::{Collective, Rank};
use crate::sched::program::{Op, Program};
use crate::sched::tree::{FarFirstTree, NearFirstTree};

/// Classic Bruck all-gather (nearest dimension first, Fig. 1). At step `d`
/// each rank sends the `min(2^d, n - 2^d)` chunks it holds for offsets
/// `[0, 2^d)` to the rank `2^d` ahead.
pub fn allgather_near_first(n: usize) -> Program {
    let mut p = Program::new(n, Collective::AllGather, "bruck_near");
    if n <= 1 {
        return p;
    }
    let t = NearFirstTree::new(n);
    let dmax = t.dmax().unwrap();
    for (step, d) in (0..=dmax).enumerate() {
        push_dim_round(&mut p, n, d, step, &offsets_near(&t, d));
    }
    p
}

/// Dimension-reversed Bruck all-gather (farthest dimension first, Fig. 3).
/// At step `d` (descending) each rank sends the chunks at source offsets
/// `o ≡ 0 (mod 2^(d+1))`, `o + 2^d < n` — 1, 2, 4, … chunks at
/// *decreasing* distance.
pub fn allgather_far_first(n: usize) -> Program {
    let mut p = Program::new(n, Collective::AllGather, "bruck_far");
    if n <= 1 {
        return p;
    }
    let t = FarFirstTree::new(n);
    let dmax = t.dmax().unwrap();
    for (step, d) in (0..=dmax).rev().enumerate() {
        push_dim_round(&mut p, n, d, step, &offsets_far(&t, d));
    }
    p
}

/// Source offsets of tree edges at dimension `d`, near-first tree.
fn offsets_near(t: &NearFirstTree, d: u32) -> Vec<usize> {
    t.edges_at_dim(d).into_iter().map(|e| e.from).collect()
}

/// Source offsets of tree edges at dimension `d`, far-first tree.
fn offsets_far(t: &FarFirstTree, d: u32) -> Vec<usize> {
    t.edges_at_dim(d).into_iter().map(|e| e.from).collect()
}

/// Emit one fully-aggregated dimension round: every rank `i` sends, to
/// `i + 2^d`, the chunks rooted at `j = i - o` for each tree-edge source
/// offset `o`, and receives the matching chunks from `i - 2^d`.
fn push_dim_round(p: &mut Program, n: usize, d: u32, step: usize, offsets: &[usize]) {
    if offsets.is_empty() {
        return;
    }
    let hop = 1usize << d;
    for i in 0..n {
        let dst: Rank = (i + hop) % n;
        let src: Rank = (i + n - hop % n) % n;
        let send_chunks: Vec<usize> = offsets.iter().map(|o| (i + n - o % n) % n).collect();
        let recv_chunks: Vec<usize> = offsets.iter().map(|o| (src + n - o % n) % n).collect();
        p.push(i, Op::send(dst, send_chunks, step));
        p.push(i, Op::recv(src, recv_chunks, false, step));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ceil_log2;
    use crate::sched::verify::verify_program;

    #[test]
    fn near_first_correct_any_n() {
        for n in 1..34 {
            verify_program(&allgather_near_first(n)).unwrap();
        }
    }

    #[test]
    fn far_first_correct_any_n() {
        for n in 1..34 {
            verify_program(&allgather_far_first(n)).unwrap();
        }
    }

    #[test]
    fn log_steps() {
        for n in [2usize, 3, 4, 7, 8, 15, 16, 31, 32, 33] {
            let want = ceil_log2(n) as usize;
            assert_eq!(allgather_near_first(n).steps, want, "near n={n}");
            assert_eq!(allgather_far_first(n).steps, want, "far n={n}");
        }
    }

    /// Fig. 1: classic Bruck on 8 ranks sends 1, 2, 4 chunks at distances
    /// 1, 2, 4. Fig. 3: reversed sends 1, 2, 4 chunks at distances 4, 2, 1.
    #[test]
    fn payload_distance_profiles() {
        let near = allgather_near_first(8);
        let prof: Vec<(usize, usize)> = near
            .rounds()
            .values()
            .map(|ms| {
                let m = &ms[0];
                (m.chunks.len(), (m.dst + 8 - m.src) % 8)
            })
            .collect();
        assert_eq!(prof, vec![(1, 1), (2, 2), (4, 4)]);

        let far = allgather_far_first(8);
        let prof: Vec<(usize, usize)> = far
            .rounds()
            .values()
            .map(|ms| {
                let m = &ms[0];
                (m.chunks.len(), (m.dst + 8 - m.src) % 8)
            })
            .collect();
        assert_eq!(prof, vec![(1, 4), (2, 2), (4, 1)]);
    }

    /// Mirrored Bruck programs implement reduce-scatter on any rank count.
    #[test]
    fn mirrored_rs_correct() {
        for n in 1..20 {
            verify_program(&allgather_near_first(n).mirror()).unwrap();
            verify_program(&allgather_far_first(n).mirror()).unwrap();
        }
    }
}
