//! Algorithm auto-selection (what NCCL's tuning model does for PAT vs
//! Ring): a closed-form α-β-γ cost estimate over the candidate schedules,
//! constrained by the intermediate-buffer budget.
//!
//! The PAT aggregation factor is derived from the buffer budget using the
//! measured accumulator law (see `sched::pat`): a reduce-scatter with
//! aggregation `a` needs `a · log2(n/a)` persistent chunk slots, an
//! all-gather needs `a` transient slots per transfer. The tuner picks the
//! largest feasible `a`, then compares PAT(a), Ring, and (log-shaped but
//! congestion-prone) far-first Bruck under the cost model and returns the
//! cheapest.

use crate::core::{ceil_log2, Algorithm, Collective};
use crate::sched::pat;
use crate::sim::CostModel;

/// A tuner decision with its predicted cost.
#[derive(Debug, Clone)]
pub struct TunerChoice {
    pub algorithm: Algorithm,
    pub predicted_seconds: f64,
    /// All evaluated candidates (algorithm, predicted seconds), best first.
    pub candidates: Vec<(Algorithm, f64)>,
}

/// Closed-form schedule cost estimator.
#[derive(Debug, Clone)]
pub struct Tuner {
    pub cost: CostModel,
    /// NIC bandwidth (bytes/s) used for serialization estimates.
    pub nic_bw: f64,
}

impl Default for Tuner {
    fn default() -> Self {
        Tuner { cost: CostModel::ib_hdr(), nic_bw: CostModel::ib_hdr_nic_bw() }
    }
}

impl Tuner {
    /// Largest PAT aggregation whose buffer need fits `buffer_slots` chunk
    /// slots for this collective.
    pub fn max_aggregation(
        &self,
        nranks: usize,
        buffer_slots: usize,
        coll: Collective,
    ) -> usize {
        let buffer_slots = buffer_slots.max(1);
        let full = pat::clamp_aggregation(nranks, usize::MAX);
        let mut best = 1;
        let mut a = 1;
        while a <= full {
            let need = match coll {
                Collective::AllGather => a,
                Collective::ReduceScatter => {
                    let levels = (ceil_log2(nranks.max(2)) as usize)
                        .saturating_sub(a.trailing_zeros() as usize)
                        .max(1);
                    a * levels
                }
            };
            if need <= buffer_slots {
                best = a;
            }
            if a >= full {
                break;
            }
            a = (a * 2).min(full);
            if a == best {
                break;
            }
        }
        best
    }

    /// Predicted wall time of a PAT schedule: per round, message overhead +
    /// serialization + local pack cost.
    pub fn predict_pat(&self, nranks: usize, a: usize, chunk_bytes: usize) -> f64 {
        let c = &self.cost;
        let mut t = 0.0;
        for round in pat::rounds(nranks, a) {
            let k = round.offsets.len();
            let bytes = k * chunk_bytes;
            t += c.alpha_base
                + bytes as f64 / self.nic_bw
                + c.pack_cost(k, bytes)
                + c.msg_gap;
        }
        t
    }

    /// Predicted wall time of the ring schedule: n-1 back-to-back single
    /// chunk transfers; the pipeline overlaps serialization, so latency is
    /// (n-1)·(α + gap) + serialization of the payload.
    pub fn predict_ring(&self, nranks: usize, chunk_bytes: usize) -> f64 {
        if nranks <= 1 {
            return 0.0;
        }
        let c = &self.cost;
        let steps = (nranks - 1) as f64;
        steps * (c.alpha_base + c.msg_gap + chunk_bytes as f64 / self.nic_bw)
    }

    /// Predicted wall time of far-first Bruck (fully aggregated): log
    /// rounds of doubling payload, plus pack costs.
    pub fn predict_bruck(&self, nranks: usize, chunk_bytes: usize) -> f64 {
        self.predict_pat(nranks, usize::MAX, chunk_bytes)
    }

    /// Choose an algorithm for `nranks`, `chunk_bytes` per rank, and a
    /// `buffer_slots`-chunk intermediate buffer.
    pub fn choose(
        &self,
        nranks: usize,
        chunk_bytes: usize,
        buffer_slots: usize,
        coll: Collective,
    ) -> TunerChoice {
        let a = self.max_aggregation(nranks, buffer_slots, coll);
        let mut candidates = vec![
            (Algorithm::Pat { aggregation: a }, self.predict_pat(nranks, a, chunk_bytes)),
            (Algorithm::Ring, self.predict_ring(nranks, chunk_bytes)),
        ];
        // Also consider intermediate aggregations (a smaller a can win when
        // pack cost dominates).
        let mut sub = a;
        while sub > 1 {
            sub /= 2;
            candidates.push((
                Algorithm::Pat { aggregation: sub },
                self.predict_pat(nranks, sub, chunk_bytes),
            ));
        }
        candidates.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap());
        TunerChoice {
            algorithm: candidates[0].0,
            predicted_seconds: candidates[0].1,
            candidates,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_messages_pick_pat_large_pick_ring_or_pat1() {
        let t = Tuner::default();
        let small = t.choose(64, 256, 1 << 20, Collective::AllGather);
        assert!(
            matches!(small.algorithm, Algorithm::Pat { aggregation } if aggregation > 1),
            "{:?}",
            small.algorithm
        );
        // At huge sizes the per-chunk pack cost and serialization dominate:
        // ring (contiguous, pipelined) or pat(a=1) (also contiguous) win.
        let large = t.choose(64, 64 << 20, 1 << 20, Collective::AllGather);
        match large.algorithm {
            Algorithm::Ring | Algorithm::Pat { aggregation: 1 } => {}
            other => panic!("large message picked {other:?}"),
        }
    }

    #[test]
    fn buffer_budget_caps_aggregation() {
        let t = Tuner::default();
        // RS on 64 ranks: a=8 needs 8*log2(64/8)=24 slots.
        assert_eq!(t.max_aggregation(64, 24, Collective::ReduceScatter), 8);
        assert_eq!(t.max_aggregation(64, 23, Collective::ReduceScatter), 4);
        assert_eq!(t.max_aggregation(64, 1, Collective::ReduceScatter), 1);
        // AG is bounded by the transfer itself.
        assert_eq!(t.max_aggregation(64, 8, Collective::AllGather), 8);
    }

    #[test]
    fn predictions_monotone_in_ranks() {
        let t = Tuner::default();
        assert!(t.predict_ring(128, 1024) > t.predict_ring(16, 1024));
        assert!(t.predict_pat(128, 8, 1024) > t.predict_pat(16, 8, 1024));
    }

    /// The tuner's pick must be within 5% of the best candidate it saw
    /// (trivially true) and PAT must beat ring by ~(n-1)/log2(n) at tiny
    /// sizes.
    #[test]
    fn pat_speedup_at_small_sizes() {
        let t = Tuner::default();
        let n = 128;
        let pat_t = t.predict_pat(n, 64, 64);
        let ring_t = t.predict_ring(n, 64);
        let speedup = ring_t / pat_t;
        let ideal = (n - 1) as f64 / (ceil_log2(n) as f64);
        assert!(
            speedup > ideal * 0.5,
            "speedup {speedup:.1} vs ideal {ideal:.1}"
        );
    }
}
