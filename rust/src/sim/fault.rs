//! Fault axes for the simulator: per-link serialization jitter and
//! link-flap windows.
//!
//! The adversary harness ([`crate::adversary`]) perturbs *delivery
//! order* on the threaded transport; this module perturbs *timing* on
//! the simulated fabric, so a schedule's robustness to network
//! misbehaviour becomes a recorded number instead of an anecdote:
//! [`robustness`] runs the same program clean and faulted and reports
//! the slowdown ratio. Both axes are fully deterministic in the model's
//! seed — a fault sweep is replayable the same way an adversary episode
//! is.
//!
//! * **Jitter** stretches each message's bottleneck serialization by a
//!   seeded per-message factor in `[0, jitter]` — the fabric analogue of
//!   the delivery layer's random holds.
//! * **Flaps** take a link down for a time window: any message whose
//!   contended start falls inside a flap window on any link of its path
//!   waits for the window to close (and then re-checks every window, so
//!   overlapping flaps compound).

use crate::core::Result;
use crate::sched::program::Program;
use crate::sim::cost::CostModel;
use crate::sim::engine::{simulate, simulate_faulted, SimReport};
use crate::sim::topology::Topology;
use crate::util::Rng;

/// One link-down window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFlap {
    /// Index into [`Topology::links`].
    pub link: usize,
    /// Window start (seconds, simulation time).
    pub t0: f64,
    /// Window length (seconds).
    pub dur: f64,
}

impl LinkFlap {
    fn end(&self) -> f64 {
        self.t0 + self.dur
    }

    /// Whether a message starting at `t` on this link is inside the
    /// window.
    fn holds(&self, t: f64) -> bool {
        t >= self.t0 && t < self.end()
    }
}

/// Deterministic fault model applied to every simulated message.
#[derive(Debug, Clone, Default)]
pub struct FaultModel {
    pub seed: u64,
    /// Max fractional serialization stretch per message (0.25 = up to
    /// +25% on the bottleneck link's serialization time).
    pub jitter: f64,
    pub flaps: Vec<LinkFlap>,
}

impl FaultModel {
    pub fn new(seed: u64, jitter: f64) -> FaultModel {
        FaultModel { seed, jitter, flaps: Vec::new() }
    }

    pub fn with_flaps(mut self, flaps: Vec<LinkFlap>) -> FaultModel {
        self.flaps = flaps;
        self
    }

    /// `count` seeded random flaps of length `dur` each, placed on random
    /// links with start times in `[0, horizon)`. Run the clean simulation
    /// first to get a realistic `horizon` (its `total_time`).
    pub fn random_flaps(
        seed: u64,
        topo: &Topology,
        horizon: f64,
        count: usize,
        dur: f64,
    ) -> Vec<LinkFlap> {
        let mut rng = Rng::new(seed ^ 0x666c_6170); // "flap"
        (0..count)
            .map(|_| LinkFlap {
                link: rng.below(topo.links.len().max(1)),
                t0: rng.f64() * horizon.max(0.0),
                dur,
            })
            .collect()
    }

    /// Push a message's contended start time past every flap window it
    /// lands in on any link of its path. Iterates to a fixed point so a
    /// start pushed out of one window into another keeps moving.
    pub fn hold_start(&self, path: &[usize], mut t0: f64) -> f64 {
        if self.flaps.is_empty() {
            return t0;
        }
        loop {
            let mut moved = false;
            for f in &self.flaps {
                if path.contains(&f.link) && f.holds(t0) {
                    t0 = f.end();
                    moved = true;
                }
            }
            if !moved {
                return t0;
            }
        }
    }

    /// Extra arrival latency for message number `msg` from `src` to
    /// `dst` on `channel` whose bottleneck serialization took `ser`
    /// seconds: `ser × jitter × u`, `u` a seeded unit hash. Purely a
    /// function of the model seed and the message coordinates.
    pub fn jitter_extra(&self, src: usize, dst: usize, channel: usize, msg: u64, ser: f64) -> f64 {
        if self.jitter <= 0.0 || ser <= 0.0 {
            return 0.0;
        }
        let mut h = self.seed ^ 0x6a69_7474_6572; // "jitter"
        for v in [src as u64, dst as u64, channel as u64, msg] {
            h ^= v.wrapping_add(0x9e37_79b9_7f4a_7c15);
            h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
            h ^= h >> 27;
        }
        let unit = (h >> 11) as f64 / (1u64 << 53) as f64;
        ser * self.jitter * unit
    }
}

/// Clean-vs-faulted comparison for one program point.
#[derive(Debug, Clone)]
pub struct Robustness {
    pub clean: SimReport,
    pub faulted: SimReport,
}

impl Robustness {
    /// Faulted completion time over clean completion time (≥ 1.0 for
    /// any non-degenerate fault model: faults only ever delay).
    pub fn slowdown(&self) -> f64 {
        if self.clean.total_time > 0.0 {
            self.faulted.total_time / self.clean.total_time
        } else {
            1.0
        }
    }
}

/// Run `p` clean and under `faults`, returning both reports. The
/// schedule-robustness number the adversary work records for the
/// simulator side.
pub fn robustness(
    p: &Program,
    topo: &Topology,
    cost: &CostModel,
    chunk_bytes: usize,
    faults: &FaultModel,
) -> Result<Robustness> {
    let clean = simulate(p, topo, cost, chunk_bytes)?;
    let faulted = simulate_faulted(p, topo, cost, chunk_bytes, faults)?;
    Ok(Robustness { clean, faulted })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Algorithm, Collective};
    use crate::sched;

    fn setup() -> (Program, Topology, CostModel) {
        let p = sched::generate(Algorithm::Ring, Collective::AllGather, 8).unwrap();
        let topo = Topology::leaf_spine(8, 4, 2, 25e9, 0.5).unwrap();
        (p, topo, CostModel::default())
    }

    #[test]
    fn zero_fault_model_matches_clean_exactly() {
        let (p, topo, cost) = setup();
        let clean = simulate(&p, &topo, &cost, 1 << 16).unwrap();
        let faulted =
            simulate_faulted(&p, &topo, &cost, 1 << 16, &FaultModel::new(7, 0.0)).unwrap();
        assert_eq!(clean.total_time, faulted.total_time);
        assert_eq!(clean.messages, faulted.messages);
    }

    #[test]
    fn jitter_is_deterministic_and_slows_completion() {
        let (p, topo, cost) = setup();
        let fm = FaultModel::new(42, 0.5);
        let a = simulate_faulted(&p, &topo, &cost, 1 << 16, &fm).unwrap();
        let b = simulate_faulted(&p, &topo, &cost, 1 << 16, &fm).unwrap();
        assert_eq!(a.total_time, b.total_time, "same seed, same timeline");
        let clean = simulate(&p, &topo, &cost, 1 << 16).unwrap();
        assert!(
            a.total_time >= clean.total_time,
            "jitter only delays: {} < {}",
            a.total_time,
            clean.total_time
        );
    }

    #[test]
    fn flap_windows_delay_messages_through_the_link() {
        let (p, topo, cost) = setup();
        let clean = simulate(&p, &topo, &cost, 1 << 16).unwrap();
        // Take every link down for the whole clean run: everything that
        // starts inside the window waits it out.
        let flaps: Vec<LinkFlap> = (0..topo.links.len())
            .map(|l| LinkFlap { link: l, t0: 0.0, dur: clean.total_time })
            .collect();
        let fm = FaultModel::new(1, 0.0).with_flaps(flaps);
        let r = robustness(&p, &topo, &cost, 1 << 16, &fm).unwrap();
        assert!(r.slowdown() > 1.0, "global flap must slow the run");
        assert_eq!(r.clean.messages, r.faulted.messages);
    }

    #[test]
    fn random_flaps_are_seeded() {
        let (_p, topo, _c) = setup();
        let a = FaultModel::random_flaps(9, &topo, 1.0, 5, 0.1);
        let b = FaultModel::random_flaps(9, &topo, 1.0, 5, 0.1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
    }
}
